"""Docs cross-reference checker (the CI ``docs-check`` leg).

Verifies, with zero third-party dependencies:

1. every ``DESIGN.md §N`` citation — in Python docstrings/comments and
   in the docs themselves — names a section heading that actually exists
   in docs/DESIGN.md (same for bare ``§N`` references *inside*
   DESIGN.md);
2. every relative markdown link ``[text](path#anchor)`` in README.md and
   docs/*.md points at a file that exists, and, when an anchor is given,
   at a heading whose GitHub slug matches;
3. every ``docs/<name>.md`` path mentioned anywhere in the source tree
   exists (catches doc renames leaving dangling docstring pointers).

Exit status 0 when everything resolves; 1 with one line per violation.

Usage:
    python tools/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: trees scanned for citations (source + docs; build junk has no docs)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools", "docs")
SCAN_MD = ("README.md", "ROADMAP.md", "CHANGES.md")

SECTION_RE = re.compile(r"^##+\s+§(\d+(?:\.\d+)?)\b", re.M)
#: `DESIGN.md §N` with optional path prefix / backtick / paren clutter
CITE_RE = re.compile(r"DESIGN\.md[`)\s]{0,3}§\s*(\d+(?:\.\d+)?)")
#: bare §N inside DESIGN.md itself (digits only: the paper's own Roman
#: §II–§IV citations are not ours to resolve)
BARE_RE = re.compile(r"§(\d+(?:\.\d+)?)")
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
DOCPATH_RE = re.compile(r"\bdocs/([\w.\-]+\.md)\b")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    slug = re.sub(r"[^\w\- ]", "", heading.strip().lower())
    return slug.replace(" ", "-")


def md_headings(path: str):
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    in_code = False
    heads = []
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
        elif not in_code and re.match(r"^#{1,6}\s", line):
            heads.append(line.lstrip("#").strip())
    return heads


def iter_files():
    for rel in SCAN_MD:
        path = os.path.join(REPO, rel)
        if os.path.exists(path):
            yield path
    for d in SCAN_DIRS:
        for root, _dirs, files in os.walk(os.path.join(REPO, d)):
            for name in sorted(files):
                if name.endswith((".py", ".md")):
                    yield os.path.join(root, name)


def main() -> int:
    errors = []
    design_path = os.path.join(REPO, "docs", "DESIGN.md")
    with open(design_path, encoding="utf-8") as fh:
        design_text = fh.read()
    sections = set(SECTION_RE.findall(design_text))
    if not sections:
        errors.append("docs/DESIGN.md: no '## §N' headings found at all")

    slugs = {}  # md path -> set of heading slugs

    def slugs_of(path):
        if path not in slugs:
            slugs[path] = {github_slug(h) for h in md_headings(path)}
        return slugs[path]

    for path in iter_files():
        rel = os.path.relpath(path, REPO)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()

        # 1. DESIGN.md §N citations resolve
        refs = set(CITE_RE.findall(text))
        if rel == os.path.join("docs", "DESIGN.md"):
            refs |= set(BARE_RE.findall(text))
        for ref in sorted(refs):
            if ref not in sections:
                errors.append(
                    f"{rel}: cites DESIGN.md §{ref} but docs/DESIGN.md has "
                    f"no '## §{ref}' heading")

        # 3. mentioned docs/*.md files exist
        for name in set(DOCPATH_RE.findall(text)):
            if not os.path.exists(os.path.join(REPO, "docs", name)):
                errors.append(f"{rel}: mentions docs/{name}, which does "
                              "not exist")

        # 2. relative markdown links are live (md files only)
        if not path.endswith(".md"):
            continue
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            fpath, _, anchor = target.partition("#")
            tpath = (os.path.normpath(
                os.path.join(os.path.dirname(path), fpath))
                if fpath else path)
            if not os.path.exists(tpath):
                errors.append(f"{rel}: link target {target!r} does not "
                              "exist")
                continue
            if anchor and tpath.endswith(".md"):
                if anchor not in slugs_of(tpath):
                    errors.append(
                        f"{rel}: anchor {target!r} matches no heading in "
                        f"{os.path.relpath(tpath, REPO)}")

    for err in errors:
        print(f"docs-check: {err}")
    print(f"docs-check: {'FAIL' if errors else 'OK'} "
          f"({len(sections)} DESIGN.md sections)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
