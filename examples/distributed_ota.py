"""Distributed OTA training on a multi-device mesh (8 simulated devices).

Demonstrates the framework path: a real transformer (reduced SmolLM family),
data-parallel edge devices on the mesh's 'data' axis, tensor parallelism on
'model', and the A-DSGD aggregation (blocked projection + AMP) replacing the
gradient all-reduce inside a partial-manual shard_map.

Run:  PYTHONPATH=src python examples/distributed_ota.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

from repro.configs import get_config                           # noqa: E402
from repro.configs.base import OTAConfig, TrainConfig          # noqa: E402
from repro.data.synthetic import TokenStream                   # noqa: E402
from repro.train.trainer import make_train_step                # noqa: E402

mesh = jax.make_mesh((4, 2), ("data", "model"))
arch = get_config("smollm_360m").reduced()
train_cfg = TrainConfig(optimizer="adam", lr=5e-3, warmup_steps=5,
                        total_steps=60, compute_dtype="float32", remat=True)
ota = OTAConfig(scheme="a_dsgd", projection="blocked", block_size=512,
                s_frac=0.25, k_frac=0.5, rademacher=True, p_avg=500.0,
                total_steps=60, amp_iters=10, mean_removal_steps=5)

ts = make_train_step(arch, train_cfg, ota, mesh, ota_axes=("data",))
print(f"model d={ts.d:,} padded={ts.d_pad:,}  OTA devices M={ts.m_devices}  "
      f"error-feedback state {ts.delta_shape}")

params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
stream = TokenStream(vocab=arch.vocab, seq_len=64, batch=16, seed=0)
step_fn = ts.jitted({"tokens": jnp.zeros((16, 64), jnp.int32)})

for step in range(30):
    # cycle a small batch set so learning is visible within a short demo
    batch = {"tokens": jnp.asarray(stream.batch_at(step % 4)["tokens"])}
    params, opt_state, delta, metrics = step_fn(
        params, opt_state, delta, batch, jnp.asarray(step),
        jax.random.PRNGKey(step))
    if step % 5 == 0:
        print(f"step {step:3d}  loss {float(metrics['global_loss']):.4f}  "
              f"frame power {float(metrics['frame_power']):.1f}")
print("done — loss should be decreasing while every gradient exchange "
      "went through the simulated wireless MAC.")
