"""Paper §VI reproduction driver: pick a figure and render its data as CSV.

Run:  PYTHONPATH=src python examples/paper_repro.py [fig2|fig3|...|fig7|thm1]
      FULL=1 ... for the paper-scale settings (M=25, B=1000, T=300).
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run as bench_run  # noqa: E402

if __name__ == "__main__":
    sys.argv = ["paper_repro"] + (sys.argv[1:] or ["fig2"])
    bench_run.main()
