"""Paper §VI reproduction driver: pick a figure and render its data as CSV.

Run:  PYTHONPATH=src python examples/paper_repro.py [fig2|fig3|...|fig7|thm1]
      FULL=1 ... for the paper-scale settings (M=25, B=1000, T=300).
"""
import sys

from benchmarks import run as bench_run

if __name__ == "__main__":
    sys.argv = ["paper_repro"] + (sys.argv[1:] or ["fig2"])
    bench_run.main()
