"""Quickstart: federated over-the-air SGD in ~40 lines.

Ten simulated edge devices collaboratively train the paper's single-layer
classifier over a bandwidth-limited Gaussian MAC with A-DSGD (analog
over-the-air aggregation), and we compare against the error-free bound.
Each run executes as ONE jitted scan over rounds (the compiled experiment
engine, docs/EXPERIMENTS.md) — no Python per-round loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import OTAConfig
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import run_compiled

# 1) data: 10 devices x 400 local samples (MNIST-surrogate, offline)
(x_train, y_train), (x_test, y_test) = make_classification(
    n_train=8000, n_test=2000, noise=6.0, seed=3)
x_dev, y_dev = federated_split(x_train, y_train, m=10, b=400, iid=True)

# 2) the channel: s = d/2 uses of a Gaussian MAC, average power 500,
#    A-DSGD = error feedback + top-k + compressive projection + AMP at the PS.
#    Every scheme name resolves through the registry in repro.core.schemes —
#    register your own with @register_scheme("my_scheme") and it runs on all
#    drivers (a_dsgd_fading adds a truncated-inversion Rayleigh MAC that way).
adsgd = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                  sigma2=1.0, total_steps=40, projection="dense",
                  amp_iters=20, mean_removal_steps=10)
fading = OTAConfig(scheme="a_dsgd_fading", s_frac=0.5, k_frac=0.25,
                   p_avg=500.0, sigma2=1.0, total_steps=40,
                   projection="dense", amp_iters=20, mean_removal_steps=10,
                   fading_threshold=0.3)
ideal = OTAConfig(scheme="ideal", total_steps=40)

# 3) train — one compiled scan per config
for name, cfg in (("error-free shared link", ideal), ("A-DSGD", adsgd),
                  ("A-DSGD (Rayleigh fading)", fading)):
    run = run_compiled(x_dev, y_dev, x_test, y_test, cfg, steps=40,
                       lr=1e-3, eval_every=10)
    print(f"{name:24s} accuracy trajectory: "
          + " ".join(f"{a:.3f}" for a in run.accs))
