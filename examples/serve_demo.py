"""Serving demo: batched greedy decoding with per-family caches.

Runs a reduced dense model and a reduced RWKV6 (recurrent state) through
prefill + decode with the serve substrate on a 2-device mesh.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

from repro.configs import get_config                           # noqa: E402
from repro.models import decode_step, init_decode_cache, init_params  # noqa: E402

for arch_id in ("smollm_360m", "rwkv6_3b"):
    cfg = get_config(arch_id).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, gen_len = 4, 8, 24
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                0, cfg.vocab)
    cache = init_decode_cache(cfg, B, prompt_len + gen_len, jnp.float32)
    tok = prompt[:, :1]
    out = [tok]
    step = jax.jit(lambda t, c, p: decode_step(params, cfg, t, c, p,
                                               compute_dtype=jnp.float32))
    for pos in range(prompt_len + gen_len - 1):
        logits, cache = step(tok, cache, pos)
        nxt = (prompt[:, pos + 1: pos + 2] if pos + 1 < prompt_len
               else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    seq = jnp.concatenate(out, 1)
    print(f"{arch_id:14s} generated {seq.shape} tokens; "
          f"sample row: {seq[0, :16].tolist()}")
