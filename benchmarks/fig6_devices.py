"""Paper Fig. 6: (M, B) scaling at fixed M*B, P-bar in {1, 500}."""
from benchmarks.common import SCALE, dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    total = 4000
    for m in (5, 10):
        b = total // m
        dev, test = dataset(iid=True, m=m, b=b)
        for p in (1.0, 500.0):
            for scheme in ("a_dsgd", "d_dsgd"):
                r = run_series("fig6", f"{scheme}_M{m}_P{int(p)}", dev, test,
                               ota(scheme, p_avg=p, s_frac=0.25), rows=rows)
                summary.append((f"fig6_{scheme}_M{m}_P{int(p)}",
                                r["us_per_call"], r["final_acc"]))
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
