"""Paper Fig. 6: (M, B) scaling at fixed M*B, P-bar in {1, 500}.

Each (M, B) pair re-splits the data (B changes with M), so M is swept at
the dataset level; within each split the P-bar axis is vmapped over the
compiled scan.  (Fixed-B device sweeps can instead vmap the ``m_active``
mask axis — see docs/EXPERIMENTS.md.)
"""
from benchmarks.common import dataset, emit, sweep_series


def main(collect=None):
    rows, summary = [], []
    total = 4000
    for m in (5, 10):
        dev, test = dataset(iid=True, m=m, b=total // m)
        _, s = sweep_series(
            "fig6", dev, test,
            {"scheme": ["a_dsgd", "d_dsgd"], "p_avg": [1.0, 500.0]},
            lambda r: f"{r['scheme']}_M{m}_P{int(r['p_avg'])}",
            rows=rows, s_frac=0.25)
        summary.extend(s)
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
