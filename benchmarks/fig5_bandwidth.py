"""Paper Fig. 5: channel bandwidth s in {d/2, 3d/10} — A-DSGD robust.

s changes the projector shape, so ``s_frac`` is a static sweep axis: four
compiled scan-over-rounds programs, no Python per-round loops.
"""
from benchmarks.common import dataset, emit, sweep_series

TAGS = {0.5: "d2", 0.3: "3d10"}


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True, m=10)
    _, s = sweep_series("fig5", dev, test,
                        {"scheme": ["a_dsgd", "d_dsgd"],
                         "s_frac": [0.5, 0.3]},
                        lambda r: f"{r['scheme']}_s{TAGS[r['s_frac']]}",
                        rows=rows)
    summary.extend(s)
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
