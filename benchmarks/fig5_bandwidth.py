"""Paper Fig. 5: channel bandwidth s in {d/2, 3d/10} — A-DSGD robust."""
from benchmarks.common import dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True, m=10)
    for s_frac, tag in ((0.5, "d2"), (0.3, "3d10")):
        for scheme in ("a_dsgd", "d_dsgd"):
            r = run_series("fig5", f"{scheme}_s{tag}", dev, test,
                           ota(scheme, s_frac=s_frac), rows=rows)
            summary.append((f"fig5_{scheme}_s{tag}", r["us_per_call"],
                            r["final_acc"]))
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
