"""Paper Fig. 4: P-bar in {200, 1000} — A-DSGD robust, D-DSGD degrades."""
from benchmarks.common import dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    for p in (200.0, 1000.0):
        for scheme in ("a_dsgd", "d_dsgd"):
            r = run_series("fig4", f"{scheme}_P{int(p)}", dev, test,
                           ota(scheme, p_avg=p), rows=rows)
            summary.append((f"fig4_{scheme}_P{int(p)}", r["us_per_call"],
                            r["final_acc"]))
    r = run_series("fig4", "ideal", dev, test, ota("ideal"), rows=rows)
    summary.append(("fig4_ideal", r["us_per_call"], r["final_acc"]))
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
