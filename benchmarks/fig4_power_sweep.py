"""Paper Fig. 4: P-bar in {200, 1000} — A-DSGD robust, D-DSGD degrades.

Per scheme, the P-bar grid is vmapped over one jitted scan-over-rounds
(tests/test_experiments.py pins the vmapped grid bitwise against looped
``run_federated`` runs).
"""
from benchmarks.common import dataset, emit, sweep_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    _, s = sweep_series("fig4", dev, test,
                        {"scheme": ["a_dsgd", "d_dsgd"],
                         "p_avg": [200.0, 1000.0]},
                        lambda r: f"{r['scheme']}_P{int(r['p_avg'])}",
                        rows=rows)
    summary.extend(s)
    _, s = sweep_series("fig4", dev, test, {"scheme": ["ideal"]},
                        lambda r: "ideal", rows=rows)
    summary.extend(s)
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
