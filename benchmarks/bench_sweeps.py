"""Sweep-engine benchmark: looped per-round dispatch vs one compiled grid.

Runs the same A-DSGD P-bar grid three ways and writes ``BENCH_sweeps.json``
at the repo root (committed; each PR can diff against it, and CI uploads it
as an artifact):

* ``looped``          — the legacy path (``run_federated``): one jitted
                        round per Python-loop iteration, host evals between
                        rounds, one compile + T dispatches per grid point.
* ``compiled_cold``   — ``run_sweep``: the whole grid as one vmapped+jitted
                        scan-over-rounds, including trace + compile time
                        (what a single figure run pays).
* ``compiled_steady`` — the same XLA program re-invoked warm: one dispatch
                        for the entire grid (the dispatch-overhead floor).

``SMOKE=1`` (CI) shrinks to 2 grid points x 3 rounds; the default CPU size
keeps the figure-scale model (d = 7850) at a reduced grid; ``FULL=1`` runs
a figure-sized grid.  On CPU at figure scale the rounds are
compute-dominated (dense AMP decode), so the steady advantage is modest;
the engine's structural win — grid x rounds dispatches collapsed to one —
is the same number that dominates on accelerators.

Usage:
    PYTHONPATH=src python benchmarks/bench_sweeps.py
    PYTHONPATH=src python benchmarks/run.py sweeps
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict, Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
# allow `python benchmarks/bench_sweeps.py` from the repo root (script mode
# puts benchmarks/ itself on sys.path, not the package's parent)
sys.path.insert(0, REPO_ROOT)

OUT_PATH = os.path.join(REPO_ROOT, "BENCH_sweeps.json")

SMOKE = bool(int(os.environ.get("SMOKE", "0")))
FULL = bool(int(os.environ.get("FULL", "0")))


def grid_spec():
    if SMOKE:
        return [200.0, 1000.0], 3
    if FULL:
        return [50.0, 200.0, 500.0, 1000.0], 50
    return [50.0, 200.0, 500.0, 1000.0], 10


def main(collect: Optional[list] = None, out_path: str = OUT_PATH) -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import SCALE, dataset, ota, run_series
    from repro.core import power
    from repro.experiments import (
        CompiledExperiment, Experiment, round_keys, run_sweep,
    )

    p_grid, steps = grid_spec()
    dev, test = dataset(iid=True)
    (xd, yd), (xte, yte) = dev, test
    base = ota("a_dsgd", total_steps=steps)
    n_points = len(p_grid)

    # --- looped reference: the legacy per-round harness, per grid point ----
    t0 = time.time()
    looped_final = []
    for p in p_grid:
        cfg = dataclasses.replace(base, p_avg=p)
        r = run_series("bench_sweeps", f"a_dsgd_P{int(p)}", dev, test, cfg,
                       steps=steps)
        looped_final.append(r["final_acc"])
    looped_s = time.time() - t0

    # --- compiled engine, cold: trace + compile + run ----------------------
    t0 = time.time()
    res = run_sweep(dev, test, base, {"p_avg": p_grid}, steps=steps,
                    lr=SCALE.lr, eval_every=SCALE.eval_every)
    compiled_cold_s = time.time() - t0

    # --- compiled engine, steady: the warm program, one dispatch -----------
    exp = Experiment(cfg=base, steps=steps, lr=SCALE.lr,
                     eval_every=SCALE.eval_every)
    ce = CompiledExperiment(xd, yd, xte, yte, exp)
    p_rows = jnp.asarray(np.stack([
        power.schedule_array(steps, p, base.power_schedule)
        for p in p_grid]).astype(np.float32))
    keys = jnp.stack([round_keys(steps) for _ in p_grid])
    fn = jax.jit(jax.vmap(ce.run, in_axes=({"p_sched": 0}, 0)))
    jax.block_until_ready(fn({"p_sched": p_rows}, keys))      # warm it
    t0 = time.time()
    out = fn({"p_sched": p_rows}, keys)
    jax.block_until_ready(out)
    compiled_steady_s = time.time() - t0

    # sanity: engine == loop, point for point (bitwise per the parity tests)
    compiled_final = [r["final_acc"] for r in res.records]
    max_dev = max(abs(a - b) for a, b in zip(looped_final, compiled_final))

    results = {
        "backend": jax.default_backend(),
        "smoke": SMOKE,
        "grid_points": n_points,
        "rounds": steps,
        "looped_s": round(looped_s, 3),
        "compiled_cold_s": round(compiled_cold_s, 3),
        "compiled_steady_s": round(compiled_steady_s, 3),
        "speedup_cold": round(looped_s / max(compiled_cold_s, 1e-9), 2),
        "speedup_steady": round(looped_s / max(compiled_steady_s, 1e-9), 2),
        "max_final_acc_deviation": float(max_dev),
    }
    for name in ("looped", "compiled_cold", "compiled_steady"):
        us = results[f"{name}_s"] / (n_points * steps) * 1e6
        results[f"{name}_us_per_round"] = round(us, 1)
        print(f"  {name:16s} {results[name + '_s']:8.2f} s total"
              f"  {us:10.1f} us/round", flush=True)
        if collect is not None:
            collect.append((f"sweeps/{name}", us,
                            results["speedup_steady"]))
    print(f"  max |looped - compiled| final acc: {max_dev:.2e}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    main()
