"""Streamed fedllm benchmark: rounds/sec and tokens/sec-while-training.

Runs the serve-while-train loop (``repro/train/fedllm.py``) at
smollm_360m scale and writes ``BENCH_llm.json`` at the repo root
(committed; gated by ``check_regression.py --strict`` in the llm-smoke CI
leg):

* ``train_us_per_round``        — one streamed OTA round (grads ->
                                  chunked encode/MAC/decode -> optimizer),
                                  steady-state (post-compile).
* ``serve_train_us_per_round``  — the same round plus the between-rounds
                                  serve traffic (publish + prefill +
                                  greedy decode batch): what a user of the
                                  live global params observes.
* ``compiled_cold_us_per_round``— first round including trace+compile
                                  (reported, never gated).
* ``rounds_per_sec`` / ``tokens_per_sec_while_training`` — the headline
                                  derived rates (not ``_us_per_round``
                                  keys, so reported-not-gated).

``SMOKE=1`` (CI) runs the ``.reduced()`` smollm_360m (2 layers, d_model
128 — the CPU-feasible stand-in at the same code path); the default/FULL
sizes raise rounds and chunk budget.  The demo's built-in acceptance
checks run either way: >= 2 OTA rounds, >= 1 decode batch between rounds,
published params bitwise-equal the decoded globals.

Usage:
    PYTHONPATH=src python benchmarks/bench_llm.py
    PYTHONPATH=src python benchmarks/run.py llm
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, REPO_ROOT)

OUT_PATH = os.path.join(REPO_ROOT, "BENCH_llm.json")

SMOKE = bool(int(os.environ.get("SMOKE", "0")))
FULL = bool(int(os.environ.get("FULL", "0")))


def bench_spec():
    """(reduced, rounds, m, chunk_size, decode_steps)."""
    if SMOKE:
        return True, 2, 3, 1 << 14, 2
    if FULL:
        return False, 3, 4, 1 << 18, 8
    return True, 3, 4, 1 << 15, 4


def main(collect: Optional[list] = None, out_path: str = OUT_PATH) -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import OTAConfig, TrainConfig, ota_overrides
    from repro.experiments.engine import round_keys
    from repro.launch.mesh import make_local_mesh
    from repro.train.fedllm import CompiledFedLLM, serve_while_train

    reduced, rounds, m, chunk_size, decode_steps = bench_spec()
    arch = get_config("smollm_360m")
    if reduced:
        arch = arch.reduced()
    base = ota_overrides("smollm_360m")
    block = min(base.block_size, max(chunk_size // 4, 256))
    ota = OTAConfig(projection="blocked", s_frac=base.s_frac,
                    k_frac=base.k_frac, rademacher=base.rademacher,
                    block_size=block)
    tc = TrainConfig(compute_dtype="float32" if reduced else "bfloat16")
    batch, seq_len, serve_batch, prompt_len = 2, 16, 2, 4

    # -- train-only: steady-state streamed round ---------------------------
    fed = CompiledFedLLM(arch, tc, ota, m=m, batch=batch, seq_len=seq_len,
                         chunk_size=chunk_size, seed=0)
    keys = round_keys(rounds + 1, 0)
    seg = jax.jit(lambda k, c, t: fed.run_segment({}, k, None, c, t))
    carry = fed.carry0()
    t0 = time.time()
    carry, _ = jax.block_until_ready(seg(keys[:1], carry, jnp.int32(0)))
    cold_s = time.time() - t0
    t0 = time.time()
    carry, _ = jax.block_until_ready(
        seg(keys[1:rounds + 1], carry, jnp.int32(1)))
    train_s = (time.time() - t0) / rounds

    # -- serve-while-train: the full demo loop -----------------------------
    mesh = make_local_mesh()
    t0 = time.time()
    out = serve_while_train(arch, rounds=rounds, ota=ota, train_cfg=tc,
                            m=m, batch=batch, seq_len=seq_len,
                            chunk_size=chunk_size, serve_batch=serve_batch,
                            prompt_len=prompt_len,
                            decode_steps=decode_steps, seed=0, mesh=mesh)
    swt_s = time.time() - t0
    assert len(out["served_tokens"]) == rounds >= 2, "demo did not serve"
    assert np.isfinite(out["losses"]).all(), "non-finite training loss"
    assert out["publish_bitwise"], "served params != decoded globals"
    served_tokens = rounds * serve_batch * (prompt_len + decode_steps)
    # the demo loop compiles its own jits inside the first round, so this
    # is an upper bound on the steady round+serve cost; the gate ratio
    # (2x) absorbs the amortisation difference across runners
    serve_round_s = swt_s / rounds

    doc = {
        "backend": jax.default_backend(),
        "smoke": SMOKE,
        "arch": "smollm_360m" + (".reduced" if reduced else ""),
        "d": fed.d,
        "n_chunks": fed.n_chunks,
        "chunk_len": fed.chunk_len,
        "m_devices": m,
        "rounds": rounds,
        "train_us_per_round": round(train_s * 1e6, 1),
        "serve_train_us_per_round": round(serve_round_s * 1e6, 1),
        "compiled_cold_us_per_round": round(cold_s * 1e6, 1),
        "rounds_per_sec": round(1.0 / train_s, 4),
        "tokens_per_sec_while_training": round(served_tokens / swt_s, 2),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(json.dumps(doc, indent=1))
    if collect is not None:
        collect.append(("llm", doc["train_us_per_round"],
                        doc["tokens_per_sec_while_training"]))
    return doc


if __name__ == "__main__":
    main()
