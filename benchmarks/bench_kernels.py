"""Kernel micro-benchmarks: blocked projection fwd/adjoint + full AMP decode.

Times the jnp (XLA) path against the Pallas kernel path at two problem
sizes and writes ``BENCH_kernels.json`` at the repo root — the start of the
kernel perf trajectory (each PR can diff against the committed numbers).

Sizes: ``SMOKE=1`` (or any non-TPU backend, where Pallas runs in interpret
mode and large shapes would measure the interpreter) uses two tiny CPU-safe
sizes; on TPU the default is two MXU-scale sizes.  Override with FULL=1.

Usage:
    PYTHONPATH=src python benchmarks/bench_kernels.py
    PYTHONPATH=src python benchmarks/run.py kernels
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_kernels.json")

#: (name, n_blocks, c, s_block, amp_iters)
SIZES_SMOKE = [
    ("tiny", 4, 128, 32, 4),
    ("small", 16, 256, 64, 8),
]
SIZES_FULL = [
    ("medium", 64, 1024, 256, 10),
    ("large", 256, 4096, 1024, 20),
]


def _time_us(fn, *args, warmup: int = 2, reps: int = 10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_size(name: str, n_blocks: int, c: int, s_block: int,
               iters: int, seed: int = 7) -> List[Dict]:
    from repro.core.amp import amp_blocked_core
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(1), (n_blocks, c), jnp.float32)
    yb = jax.random.normal(jax.random.PRNGKey(2), (n_blocks, s_block),
                           jnp.float32)
    entries = []
    for path in ("jnp", "kernel"):
        uk = path == "kernel"
        # jit every candidate: ops.* wrappers are jitted already, but the
        # jnp amp_blocked_core would otherwise dispatch eagerly op-by-op
        amp = jax.jit(lambda v: amp_blocked_core(v, seed, c, iters=iters,
                                                 chunk_blocks=8,
                                                 use_kernel=uk))
        ops_us = {
            "proj_fwd": _time_us(
                lambda v: ops.ota_project(v, seed=seed, s_block=s_block,
                                          rademacher=True, use_kernel=uk), x),
            "proj_adj": _time_us(
                lambda v: ops.ota_project_t(v, seed=seed, c=c,
                                            rademacher=True, use_kernel=uk),
                yb),
            "amp_decode": _time_us(amp, yb),
        }
        for op, us in ops_us.items():
            entries.append({"size": name, "n_blocks": n_blocks, "c": c,
                            "s_block": s_block, "amp_iters": iters,
                            "op": op, "path": path,
                            "us_per_call": round(us, 1)})
            print(f"  {name:8s} {op:10s} {path:6s} {us:10.1f} us/call",
                  flush=True)
    return entries


def main(collect: Optional[list] = None, out_path: str = OUT_PATH) -> Dict:
    smoke = bool(int(os.environ.get("SMOKE", "0"))) or (
        jax.default_backend() != "tpu"
        and not bool(int(os.environ.get("FULL", "0"))))
    sizes = SIZES_SMOKE if smoke else SIZES_FULL
    results = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "smoke": smoke,
        "entries": [],
    }
    for spec in sizes:
        results["entries"].extend(bench_size(*spec))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    if collect is not None:
        for e in results["entries"]:
            if e["op"] == "amp_decode":
                collect.append((f"kernels/{e['size']}/{e['path']}",
                                e["us_per_call"], "amp_decode"))
    return results


if __name__ == "__main__":
    main()
