"""Fig. 9 (beyond-paper): the channel-realism axis of the fading suite.

Reproduces the qualitative trend of the fading follow-ups (Amiri & Gunduz,
arXiv:1907.09769; Amiri, Duman & Gunduz, arXiv:1907.03909) on the
deterministic surrogate: A-DSGD accuracy under

* ``perfect``   — truncated channel inversion with perfect CSI
                  (``a_dsgd_fading``),
* ``csi_err``   — inversion driven by a noisy estimate
                  (``a_dsgd_csi_err``; the whole ``csi_err_var`` grid and
                  the seed replicas ride ONE vmapped compiled program), and
* ``blind``     — no CSI at the transmitters, K-antenna PS combining
                  (``a_dsgd_blind``),

with the ordering  ``blind <= csi_err <= perfect``  and the csi-err gap
widening as the estimation error grows.  The script *asserts* the ordering
on seed-averaged final accuracies (this is the CI smoke gate for the
scenario suite) and emits the usual ``figure,series,step,acc`` rows plus
``fig9_gap`` rows with the accuracy gap to perfect CSI per series.

``SMOKE=1`` shrinks rounds/seeds for CI; ``FULL=1`` (benchmarks.common)
restores paper-scale M/B/T.
"""

import os
import sys

# allow `python benchmarks/fig9_fading.py` from the repo root (script mode
# puts benchmarks/ itself on sys.path, not the package's parent)
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import SCALE, dataset, emit  # noqa: E402

SMOKE = bool(int(os.environ.get("SMOKE", "0")))

#: CSI-error variances swept on the vmapped axis (larger = blinder devices)
ERR_VARS = (0.1, 0.8)
#: PS antenna count for the blind scheme: deliberately far below the
#: hardening regime (K >> M), so the combiner's residual misalignment and
#: noise enhancement (~M/K) cost enough accuracy that the ordering gate has
#: a robust margin at smoke scale (K=8+ closes most of the gap; K ~ 100x M
#: approaches the AWGN link, per tests/test_fading.py)
PS_ANTENNAS = 2
#: truncation threshold shared by the CSI-driven schemes
THRESHOLD = 0.3
#: seed replicas averaged per grid point (common fading realisation across
#: schemes: the comparison is paired)
SEEDS = (0, 1) if SMOKE else (0, 1, 2)


def _sweep(dev, test, base, axes, steps):
    from repro.experiments import run_sweep

    return run_sweep(
        dev,
        test,
        base,
        axes,
        steps=steps,
        lr=SCALE.lr,
        eval_every=SCALE.eval_every,
    )


def main(collect=None):
    from benchmarks.common import ota

    steps = 16 if SMOKE else SCALE.steps
    dev, test = dataset(iid=True)
    kw = dict(
        total_steps=steps,
        fading_threshold=THRESHOLD,
        ps_antennas=PS_ANTENNAS,
    )
    rows, summary = [], []
    finals = {}  # series -> seed-averaged final accuracy

    def series_rows(series, recs):
        accs = [rec["accs"] for rec in recs]
        mean_accs = [sum(col) / len(col) for col in zip(*accs)]
        for i, acc in enumerate(mean_accs):
            step = min(i * SCALE.eval_every, steps - 1)
            rows.append(f"fig9,{series},{step},{acc:.4f}")
        finals[series] = mean_accs[-1]
        us = sum(rec["us_per_call"] for rec in recs) / len(recs)
        summary.append((f"fig9_{series}", us, mean_accs[-1]))

    res = _sweep(dev, test, ota("a_dsgd_fading", **kw), {"seed": list(SEEDS)}, steps)
    series_rows("perfect", res.records)

    res = _sweep(
        dev,
        test,
        ota("a_dsgd_csi_err", **kw),
        {"csi_err_var": list(ERR_VARS), "seed": list(SEEDS)},
        steps,
    )
    for ev in ERR_VARS:
        recs = [r for r in res.records if r["csi_err_var"] == ev]
        series_rows(f"csi_err_v{ev}", recs)

    res = _sweep(dev, test, ota("a_dsgd_blind", **kw), {"seed": list(SEEDS)}, steps)
    series_rows(f"blind_K{PS_ANTENNAS}", res.records)

    # --- the fading-paper trend: blind <= csi_err <= perfect -------------
    perfect = finals["perfect"]
    blind = finals[f"blind_K{PS_ANTENNAS}"]
    for series, acc in finals.items():
        rows.append(f"fig9_gap,{series},{steps - 1},{perfect - acc:.4f}")
    emit(rows)
    lo, hi = (finals[f"csi_err_v{v}"] for v in (max(ERR_VARS), min(ERR_VARS)))
    order = (
        f"# ordering: blind {blind:.4f}"
        f" <= csi_err(v={max(ERR_VARS)}) {lo:.4f}"
        f" <= csi_err(v={min(ERR_VARS)}) {hi:.4f}"
        f" <= perfect {perfect:.4f}"
    )
    print(order)
    tol = 0.01  # seed-averaged; allow a whisker of eval noise
    ok = blind <= lo + tol and lo <= hi + tol and hi <= perfect + tol
    print(f"# fig9 ordering_ok={ok}")
    if not ok:
        raise SystemExit("fig9: fading-suite accuracy ordering violated")
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
