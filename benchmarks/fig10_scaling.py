"""Fig. 10 (extension): population scaling — sampled K-cohorts over large M.

Two series through :mod:`repro.population`, the sampled-cohort engine:

* ``full``    — the paper's fixed-total-dataset regime (Fig. 6's x-axis,
  K == M): growing M at constant M*B splits the same pool thinner; the
  OTA sum still aligns all M gradients, so accuracy must not degrade
  (at low P-bar it improves — more aligned signal power over the same
  receiver noise).
* ``sampled`` — the population regime: a fixed K-device cohort sampled
  per round from M = 10^2 .. 10^4+ devices over a *fixed* pool, banked
  error-feedback state (capacity < M), per-round scan unchanged.  The
  cohort sees the same K gradients regardless of M, so accuracy must be
  flat in M (the tolerance-banded gate below) — the engine's claim that
  population size costs memory O(capacity * d), not convergence.

Both gates are asserted at the end; a violation exits non-zero, which is
how the CI ``population-smoke`` leg consumes this file.  Writes
``BENCH_population.json`` (committed; gated by check_regression.py like
the other BENCH files — the steady-state ``population_us_per_round`` is
the per-round dispatch+compute cost of the compiled population scan at
the largest M).

Usage:
    PYTHONPATH=src python benchmarks/fig10_scaling.py          # figure scale
    SMOKE=1 PYTHONPATH=src python benchmarks/fig10_scaling.py  # CI leg
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, REPO_ROOT)

OUT_PATH = os.path.join(REPO_ROOT, "BENCH_population.json")

SMOKE = bool(int(os.environ.get("SMOKE", "0")))
FULL = bool(int(os.environ.get("FULL", "0")))

#: accuracy tolerance bands for the scaling gates (SMOKE runs are short and
#: small, so the bands are loose there; the claim is "no degradation", not
#: "strict improvement" — seed noise at reduced scale is a few points)
TOL_FULL = 0.06 if SMOKE else 0.04
TOL_SAMPLED = 0.06 if SMOKE else 0.04


def spec():
    if SMOKE:
        return dict(m_full=(5, 10), total=2000, m_sampled=(100, 10_000),
                    k=32, b=32, steps=12, capacity=2048)
    if FULL:
        return dict(m_full=(5, 10, 25), total=25_000,
                    m_sampled=(100, 1000, 10_000, 100_000), k=64, b=64,
                    steps=100, capacity=8192)
    return dict(m_full=(5, 10, 20), total=4000,
                m_sampled=(100, 1000, 10_000), k=32, b=32, steps=30,
                capacity=4096)


def main(collect: Optional[list] = None, out_path: str = OUT_PATH) -> Dict:
    import jax

    from benchmarks.common import SCALE, dataset, emit, ota
    from repro.data.partition import population_partition
    from repro.data.synthetic import make_classification
    from repro.experiments.engine import round_keys
    from repro.population import (
        CompiledPopulation, PopulationConfig, PopulationData,
        PopulationExperiment, run_population,
    )

    sp = spec()
    steps = sp["steps"]
    eval_every = max(1, min(SCALE.eval_every, steps // 3))
    rows, summary = [], []
    results: Dict = {"backend": jax.default_backend(), "smoke": SMOKE,
                     "rounds": steps}

    # --- full participation at fixed M*B: the paper's device axis ---------
    cfg_full = ota("a_dsgd", total_steps=steps, p_avg=1.0)
    full_acc: Dict[int, float] = {}
    for m in sp["m_full"]:
        (xd, yd), test = dataset(iid=True, m=m, b=sp["total"] // m)
        pop = PopulationConfig(m_total=m, k_cohort=m)
        run = run_population(PopulationData.from_dense(xd, yd), *test,
                             cfg_full, pop, steps=steps, lr=SCALE.lr,
                             eval_every=eval_every)
        full_acc[m] = run.accs[-1]
        for i, acc in enumerate(run.accs):
            step = min(i * eval_every, steps - 1)
            rows.append(f"fig10,full_M{m},{step},{acc:.4f}")
        summary.append((f"fig10_full_M{m}", 0.0, run.accs[-1]))
        results[f"full_acc_M{m}"] = round(run.accs[-1], 4)

    # --- sampled K-cohort over a fixed pool: the population axis ----------
    cfg = ota("a_dsgd", total_steps=steps)
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=SCALE.n_train, n_test=SCALE.n_test, noise=SCALE.noise,
        seed=3)
    sampled_acc: Dict[int, float] = {}
    timing_cp = None
    for m in sp["m_sampled"]:
        part = population_partition(ytr, m=m, b=sp["b"], kind="iid", seed=0)
        pdata = PopulationData.from_pool(xtr, ytr, part)
        pop = PopulationConfig(m_total=m, k_cohort=sp["k"],
                               capacity=min(sp["capacity"], m))
        run = run_population(pdata, xte, yte, cfg, pop, steps=steps,
                             lr=SCALE.lr, eval_every=eval_every)
        sampled_acc[m] = run.accs[-1]
        for i, acc in enumerate(run.accs):
            step = min(i * eval_every, steps - 1)
            rows.append(f"fig10,sampled_M{m},{step},{acc:.4f}")
        summary.append((f"fig10_sampled_M{m}", 0.0, run.accs[-1]))
        results[f"sampled_acc_M{m}"] = round(run.accs[-1], 4)
        if m == max(sp["m_sampled"]):
            timing_cp = CompiledPopulation(
                pdata, xte, yte,
                PopulationExperiment(cfg=cfg, pop=pop, steps=steps,
                                     lr=SCALE.lr, eval_every=eval_every))

    # --- timing: the compiled population scan at the largest M ------------
    fn = jax.jit(timing_cp.run)
    keys = round_keys(steps)
    t0 = time.time()
    jax.block_until_ready(fn({}, keys))
    cold_s = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(fn({}, keys))
    steady_s = time.time() - t0
    results["compiled_cold_s"] = round(cold_s, 3)
    results["population_s"] = round(steady_s, 3)
    results["compiled_cold_us_per_round"] = round(cold_s / steps * 1e6, 1)
    results["population_us_per_round"] = round(steady_s / steps * 1e6, 1)
    m_big = max(sp["m_sampled"])
    banks = timing_cp.pstate0.banks
    results["state_bytes_banked"] = int(banks.deltas.nbytes)
    results["state_bytes_dense_equiv"] = int(m_big * timing_cp.d * 4)
    print(f"  population (M={m_big}, K={sp['k']}): "
          f"{results['population_us_per_round']:.1f} us/round steady, "
          f"banked state {banks.deltas.nbytes / 1e6:.1f} MB vs "
          f"{m_big * timing_cp.d * 4 / 1e6:.1f} MB dense", flush=True)
    if collect is not None:
        collect.append(("fig10/population",
                        results["population_us_per_round"],
                        sampled_acc[m_big]))
        collect.extend(summary)

    emit(rows)

    # --- the scaling gates -------------------------------------------------
    ms = sorted(full_acc)
    ok_full = full_acc[ms[-1]] >= full_acc[ms[0]] - TOL_FULL
    print(f"gate full:    acc(M={ms[-1]}) = {full_acc[ms[-1]]:.4f} >= "
          f"acc(M={ms[0]}) - {TOL_FULL} = {full_acc[ms[0]] - TOL_FULL:.4f} "
          f"-> {'ok' if ok_full else 'FAILED'}")
    ms = sorted(sampled_acc)
    ok_sampled = sampled_acc[ms[-1]] >= sampled_acc[ms[0]] - TOL_SAMPLED
    print(f"gate sampled: acc(M={ms[-1]}) = {sampled_acc[ms[-1]]:.4f} >= "
          f"acc(M={ms[0]}) - {TOL_SAMPLED} = "
          f"{sampled_acc[ms[0]] - TOL_SAMPLED:.4f} "
          f"-> {'ok' if ok_sampled else 'FAILED'}")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    if not (ok_full and ok_sampled):
        raise SystemExit("fig10 scaling gate failed")
    return results


if __name__ == "__main__":
    main()
