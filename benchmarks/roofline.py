"""Roofline analysis (deliverable g): derive the three terms per
(arch x shape x mesh) from the dry-run artifacts in results/dryrun/.

  compute    = FLOPs / (chips x 197 TFLOP/s)
  memory     = HBM bytes accessed / (chips x 819 GB/s)
  collective = collective bytes / (chips x 50 GB/s link)

Sources: memory/collective come from the compiled per-device module
(cost_analysis 'bytes accessed'; HLO-parsed collective output bytes).
FLOPs use an ANALYTIC workload model (6 N_active D + attention quadratic +
the OTA encode/decode pipeline): XLA's cost_analysis counts lax.scan bodies
ONCE (not x trip-count), so raw HLO FLOPs under-count scanned stacks — both
numbers are reported; MODEL_FLOPS / FLOPs_used flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, active_param_count, get_config, ota_overrides
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def analytic_flops(arch_id: str, shape_id: str, kind: str,
                   aggregator: Optional[str], m_devices: int = 16,
                   n_shards: int = 16, n_chips: int = 256) -> Dict[str, float]:
    """Global FLOPs model. Returns dict with model/train/ota components."""
    cfg = get_config(arch_id)
    shape = INPUT_SHAPES[shape_id]
    n_active = active_param_count(cfg)
    B, L = shape.global_batch, shape.seq_len
    d_attn = cfg.n_heads * cfg.resolved_head_dim
    n_attn = sum(1 for k in cfg.blocks() if k in ("attn", "swa", "moe"))
    if cfg.shared_attn_every:
        n_attn += cfg.n_layers // cfg.shared_attn_every
    if kind == "train":
        tokens = B * L
        fwd = 2.0 * n_active * tokens + 2.0 * B * L * L * d_attn * n_attn
        model = 3.0 * fwd                 # fwd + 2x bwd
        total = model * (4.0 / 3.0)       # block remat ~ one extra fwd
        ota = 0.0
        if aggregator == "a_dsgd":
            oc = ota_overrides(arch_id)
            d = active_param_count(cfg) if cfg.moe is None else \
                sum(x.size for x in [])  # placeholder, replaced below
            d = _param_total(cfg)
            s_block = oc.s_frac * oc.block_size
            encode = 12.0 * d * s_block * m_devices          # gen + matmul
            decode = (10.0 + 4.0 * oc.amp_iters) * d * s_block \
                * (n_chips / n_shards)   # replicated across data rows
            ota = encode + decode
        return {"model_flops": 6.0 * n_active * tokens, "total": total + ota,
                "ota": ota}
    if kind == "prefill":
        tokens = B * L
        fwd = 2.0 * n_active * tokens + 2.0 * B * L * L * d_attn * n_attn
        return {"model_flops": 2.0 * n_active * tokens, "total": fwd,
                "ota": 0.0}
    # decode: one token, KV-cache attention reads
    fwd = 2.0 * n_active * B + 4.0 * B * L * d_attn * n_attn
    return {"model_flops": 2.0 * n_active * B, "total": fwd, "ota": 0.0}


def _param_total(cfg) -> float:
    from repro.configs import approx_param_count
    return float(approx_param_count(cfg))


def dominant_advice(dom: str, info: Dict) -> str:
    if dom == "collective":
        return ("shrink psum payload (lower s_frac / fewer OTA replicas) or "
                "overlap the MAC all-reduce with backward compute")
    if dom == "memory":
        return ("cut HBM traffic: fuse EF+sparsify (Pallas), drop the "
                "flatten/unflatten resharding via leafwise aggregation, "
                "bf16 Delta")
    return ("reduce AMP iterations / shard the redundant PS decode across "
            "data rows; MXU-align projection tiles")


def load_rows(mesh_filter: Optional[str] = None) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            info = json.load(f)
        if "skipped" in info:
            info["tag"] = os.path.basename(path)[:-5]
            rows.append(info)
            continue
        if mesh_filter and info["mesh"] != mesh_filter:
            continue
        n = info["n_chips"]
        af = analytic_flops(info["arch"], info["shape"], info["kind"],
                            info.get("aggregator"), n_chips=n)
        t_comp = af["total"] / (n * PEAK_FLOPS_BF16)
        t_mem = info["bytes_accessed"] / HBM_BW          # per-device already
        t_coll = info["collective_bytes"]["total"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        rows.append({
            **info,
            "tag": os.path.basename(path)[:-5],
            "flops_analytic": af["total"],
            "model_flops": af["model_flops"],
            "ota_flops": af["ota"],
            "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "dominant": dom,
            "useful_ratio": af["model_flops"] / max(af["total"], 1.0),
            "advice": dominant_advice(dom, info),
        })
    return rows


def main(collect=None):
    rows = load_rows()
    hdr = ("arch,shape,mesh,variant,aggregator,t_compute_s,t_memory_s,"
           "t_collective_s,dominant,model/total_flops,temp_GiB_per_dev")
    print(hdr)
    for r in rows:
        if "skipped" in r:
            print(f"{r['tag']},SKIPPED({r['skipped'][:40]})")
            continue
        tmp = (r["mem_per_device"]["temp_bytes"] or 0) / 2**30
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['variant']},"
              f"{r.get('aggregator')},{r['t_compute']:.4f},"
              f"{r['t_memory']:.4f},{r['t_collective']:.4f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{tmp:.2f}")
        if collect is not None:
            collect.append((f"roofline_{r['tag']}", 0.0, r["dominant"]))
    out = os.path.join(RESULTS, "..", "roofline_table.json")
    with open(out, "w") as f:
        json.dump([{k: v for k, v in r.items() if k != "advice"}
                   for r in rows], f, indent=1, default=str)
    return rows


if __name__ == "__main__":
    main()
