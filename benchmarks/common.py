"""Shared harness for the paper-figure benchmarks (§VI).

Every figure benchmark prints CSV rows:
    figure,series,step,test_accuracy
plus a summary row  ``name,us_per_call,derived``  (derived = final accuracy)
for benchmarks/run.py.

The figures run on the compiled sweep engine (:mod:`repro.experiments`,
docs/DESIGN.md §6): each grid is grouped into vmapped+jitted
scans-over-rounds via :func:`sweep_series` instead of a Python per-round
loop per grid point.  :func:`run_series` keeps the looped reference path
(``run_federated``) for timing comparisons (benchmarks/bench_sweeps.py) —
both produce identical rows (pinned by tests/test_experiments.py).

Scale: the default is a CPU-sized rendition (the paper's exact d = 7850
single-layer model, fewer devices/steps); ``FULL=1`` env restores the paper's
M=25, B=1000, T=300 settings.  MNIST is replaced by the deterministic
surrogate (docs/DESIGN.md §7) — claims are validated in relative terms.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import OTAConfig
from repro.core.schemes import PAPER_SCHEMES, SCHEME_REGISTRY  # noqa: F401
from repro.data.synthetic import federated_split, make_classification
from repro.train.paper_repro import run_federated

FULL = bool(int(os.environ.get("FULL", "0")))


@dataclass
class Scale:
    m: int = 25 if FULL else 10
    b: int = 1000 if FULL else 400
    n_train: int = 60000 if FULL else 8000
    n_test: int = 10000 if FULL else 2000
    steps: int = 300 if FULL else 30
    amp_iters: int = 25 if FULL else 12
    eval_every: int = 10 if FULL else 5
    noise: float = 6.0          # surrogate difficulty: schemes separate
    lr: float = 1e-3


SCALE = Scale()


def dataset(iid: bool = True, m: Optional[int] = None,
            b: Optional[int] = None, seed: int = 3,
            partition: str = "", beta: float = 1.0):
    """Surrogate dataset split over M devices.

    ``partition`` selects any :mod:`repro.data.partition` kind
    (``iid`` | ``label_shards`` | ``dirichlet`` with bias knob ``beta``);
    empty keeps the paper's two protocols via ``iid``.
    """
    m = m or SCALE.m
    b = b or SCALE.b
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=SCALE.n_train, n_test=SCALE.n_test, noise=SCALE.noise,
        seed=seed)
    xd, yd = federated_split(xtr, ytr, m=m, b=b, iid=iid, seed=0,
                             kind=partition, beta=beta)
    return (xd, yd), (xte, yte)


def ota(scheme: str, **kw) -> OTAConfig:
    """Figure-scale OTAConfig for a registered scheme name."""
    if scheme not in SCHEME_REGISTRY:
        raise KeyError(f"unknown scheme {scheme!r}; registered: "
                       f"{', '.join(sorted(SCHEME_REGISTRY))}")
    base = dict(scheme=scheme, s_frac=0.5, p_avg=500.0,
                total_steps=SCALE.steps, projection="dense",
                amp_iters=SCALE.amp_iters, mean_removal_steps=min(
                    20, SCALE.steps // 3),
                # k = s/4 recovers better than the paper's k = s/2 at our
                # reduced M (union-support pressure on AMP); FULL keeps s/2
                k_frac=0.5 if FULL else 0.25)
    base.update(kw)
    return OTAConfig(**base)


def run_series(fig: str, series: str, dev_data, test_data, cfg: OTAConfig,
               steps: Optional[int] = None, lr: Optional[float] = None,
               rows: Optional[List[str]] = None) -> Dict:
    (xd, yd), (xte, yte) = dev_data, test_data
    steps = steps or SCALE.steps
    t0 = time.time()
    run = run_federated(xd, yd, xte, yte, cfg, steps=steps,
                        lr=lr or SCALE.lr, eval_every=SCALE.eval_every)
    dt = time.time() - t0
    out_rows = rows if rows is not None else []
    for i, acc in enumerate(run.accs):
        step = min(i * SCALE.eval_every, steps - 1)
        out_rows.append(f"{fig},{series},{step},{acc:.4f}")
    return {"final_acc": run.accs[-1], "us_per_call": dt / steps * 1e6,
            "rows": out_rows, "run": run}


def sweep_series(fig: str, dev_data, test_data, axes: Dict[str, Sequence],
                 series_fn: Callable[[Dict], str],
                 rows: Optional[List[str]] = None,
                 steps: Optional[int] = None, lr: Optional[float] = None,
                 **ota_kw) -> Tuple[object, List]:
    """Run a figure grid on the compiled sweep engine.

    ``axes`` follows :func:`repro.experiments.run_sweep` (vmapped:
    ``p_avg`` / ``power_schedule`` / ``seed`` / ``m_active``; static: any
    OTAConfig field, e.g. ``scheme`` / ``s_frac``); ``ota_kw`` fills the
    base OTAConfig via :func:`ota`.  Emits the same
    ``figure,series,step,acc`` rows and ``(name, us_per_call, final_acc)``
    summary entries as :func:`run_series` — ``series_fn(record)`` names
    each grid point.  Returns (SweepResult, summary).
    """
    from repro.experiments import run_sweep
    steps = steps or SCALE.steps
    scheme0 = (axes["scheme"][0] if "scheme" in axes
               else ota_kw.pop("scheme"))
    base = ota(scheme0, total_steps=steps, **ota_kw)
    res = run_sweep(dev_data, test_data, base, axes, steps=steps,
                    lr=lr or SCALE.lr, eval_every=SCALE.eval_every)
    summary = []
    for rec in res.records:
        series = series_fn(rec)
        if rows is not None:
            for i, acc in enumerate(rec["accs"]):
                step = min(i * SCALE.eval_every, steps - 1)
                rows.append(f"{fig},{series},{step},{acc:.4f}")
        summary.append((f"{fig}_{series}", rec["us_per_call"],
                        rec["final_acc"]))
    return res, summary


def emit(rows: List[str]) -> None:
    print("figure,series,step,test_accuracy")
    for r in rows:
        print(r)
