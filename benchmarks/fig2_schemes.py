"""Paper Fig. 2: test accuracy of all five schemes, IID and non-IID.

Each data split runs as one engine grid: the five schemes are static axis
values (per-scheme compiles), every run a single jitted scan over rounds.
"""
from benchmarks.common import PAPER_SCHEMES, dataset, emit, sweep_series


def main(collect=None):
    rows, summary = [], []
    for iid, tag in ((True, "iid"), (False, "noniid")):
        dev, test = dataset(iid=iid)
        _, s = sweep_series("fig2", dev, test,
                            {"scheme": list(PAPER_SCHEMES)},
                            lambda r: f"{r['scheme']}_{tag}", rows=rows)
        summary.extend(s)
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
