"""Paper Fig. 2: test accuracy of all five schemes, IID and non-IID."""
from benchmarks.common import PAPER_SCHEMES, SCALE, dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    for iid, tag in ((True, "iid"), (False, "noniid")):
        dev, test = dataset(iid=iid)
        for scheme in PAPER_SCHEMES:
            r = run_series("fig2", f"{scheme}_{tag}", dev, test,
                           ota(scheme), rows=rows)
            summary.append((f"fig2_{scheme}_{tag}", r["us_per_call"],
                            r["final_acc"]))
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
