"""Fig. 13 (beyond-paper): cell geometry and subband scheduling.

The paper's channel model is distance-free: every device sees the same
statistics.  This figure grounds it in a cell (``repro.core.geometry``,
DESIGN.md §12): devices drop area-uniformly in a disk of radius R around
the base station, and a normalised power law ``(d/d0)^-gamma`` scales each
device's received-power factor on top of the small-scale Rayleigh fading.

Panel A — cell size: the same A-DSGD run at growing R.  Shrinking
large-scale gains lower every device's effective SNR, so final accuracy
must degrade monotonically in R (the gate).  At R = d0 = 100 m the power
law is neutral; each 4x radius step costs ~18 dB at gamma = 3.

Panel B — subband scheduling: bandwidth split into S subbands, a
registered scheduler (``repro.core.scheduling``) picking which S of the M
devices transmit each round, at a fixed moderate radius.  With few
subbands the max-SNR policy (``gain_ranked``) must retain at least the
gains-blind cycle (``round_robin``) — it spends the same channel uses on
strictly stronger links, and the silenced devices' updates are not lost
but banked by error feedback (the gate; ``prop_fair`` rides along
ungated as the fairness/throughput midpoint).

The whole grid rides the sweep engine: ``cell_radius`` / ``n_subbands``
are vmapped traced scalars, ``scheduler`` is a static axis (one compiled
program per policy, docs/DESIGN.md §12).

Timings land in ``BENCH_geometry.json`` (committed; gated by
check_regression.py like the other BENCH files).

Usage:
    PYTHONPATH=src python benchmarks/fig13_geometry.py          # figure scale
    SMOKE=1 PYTHONPATH=src python benchmarks/fig13_geometry.py  # CI leg
"""

import json
import os
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, REPO_ROOT)

from benchmarks.common import SCALE, dataset, emit  # noqa: E402

SMOKE = bool(int(os.environ.get("SMOKE", "0")))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_geometry.json")

#: panel A radii (meters): d0-neutral, then two 4x steps (~18 dB each at
#: gamma = 3) — spans boost, paper-like, and power-starved regimes
RADII = (100.0, 400.0, 1600.0)
PATH_LOSS_EXP = 3.0
#: panel B: moderate radius (links weakened but decodable) and a small
#: subband budget, where the scheduling policy actually bites
RADIUS_B = 800.0
N_SUBBANDS = 2
SCHEDULERS = ("round_robin", "gain_ranked", "prop_fair")
#: accuracy tolerance on the ordering gates (seed-averaged finals)
TOL = 0.02
#: seed replicas averaged per grid point
SEEDS = (0, 1) if SMOKE else (0, 1, 2)


def _series_rows(rows, fig, series, mean_accs, steps):
    for i, acc in enumerate(mean_accs):
        step = min(i * SCALE.eval_every, steps - 1)
        rows.append(f"{fig},{series},{step},{acc:.4f}")


def _seed_mean(records, **match):
    recs = [r for r in records
            if all(r[k] == v for k, v in match.items())]
    accs = [rec["accs"] for rec in recs]
    mean_accs = [sum(col) / len(col) for col in zip(*accs)]
    us = sum(rec["us_per_call"] for rec in recs) / len(recs)
    return mean_accs, us


def main(collect=None):
    from benchmarks.common import ota
    from repro.experiments import run_sweep

    steps = 16 if SMOKE else SCALE.steps
    dev, test = dataset()
    rows, summary, bench = [], [], {
        "smoke": SMOKE,
        "radii": list(RADII),
        "n_subbands": N_SUBBANDS,
    }

    # --- panel A: accuracy vs cell radius (no scheduler) -----------------
    base = ota("a_dsgd", total_steps=steps, fading="rayleigh",
               geometry="disk", path_loss_exp=PATH_LOSS_EXP)
    res = run_sweep(dev, test, base,
                    {"cell_radius": list(RADII), "seed": list(SEEDS)},
                    steps=steps, lr=SCALE.lr, eval_every=SCALE.eval_every)
    radius_final = {}
    for radius in RADII:
        mean_accs, us = _seed_mean(res.records, cell_radius=radius)
        name = f"fig13_R{int(radius)}"
        _series_rows(rows, "fig13", f"R{int(radius)}", mean_accs, steps)
        radius_final[radius] = mean_accs[-1]
        summary.append((name, us, mean_accs[-1]))
        bench[f"{name}_us_per_round"] = round(us / steps, 1)
        bench[f"{name}_final_acc"] = round(mean_accs[-1], 4)

    # --- panel B: scheduler policies at a small subband budget -----------
    sched_final = {}
    for sched in SCHEDULERS:
        base = ota("a_dsgd", total_steps=steps, fading="rayleigh",
                   geometry="disk", cell_radius=RADIUS_B,
                   path_loss_exp=PATH_LOSS_EXP, scheduler=sched,
                   n_subbands=N_SUBBANDS)
        res = run_sweep(dev, test, base, {"seed": list(SEEDS)},
                        steps=steps, lr=SCALE.lr,
                        eval_every=SCALE.eval_every)
        mean_accs, us = _seed_mean(res.records)
        name = f"fig13_{sched}_S{N_SUBBANDS}"
        _series_rows(rows, "fig13", f"{sched}_S{N_SUBBANDS}", mean_accs,
                     steps)
        sched_final[sched] = mean_accs[-1]
        summary.append((name, us, mean_accs[-1]))
        bench[f"{name}_us_per_round"] = round(us / steps, 1)
        bench[f"{name}_final_acc"] = round(mean_accs[-1], 4)

    emit(rows)
    print("# fig13 radius finals: " + "  ".join(
        f"R{int(r)}={radius_final[r]:.4f}" for r in RADII))
    print("# fig13 scheduler finals @S=%d: " % N_SUBBANDS + "  ".join(
        f"{s}={sched_final[s]:.4f}" for s in SCHEDULERS))

    # --- the geometry/scheduling claims this figure pins -----------------
    checks = {}
    ordered = [radius_final[r] for r in RADII]
    checks["radius_monotone_degradation"] = all(
        ordered[i] >= ordered[i + 1] - TOL for i in range(len(ordered) - 1))
    checks["radius_actually_bites"] = ordered[0] > ordered[-1] + TOL
    checks["gain_ranked_beats_round_robin"] = (
        sched_final["gain_ranked"] >= sched_final["round_robin"] - TOL)
    checks["schedulers_above_chance"] = all(
        f > 0.15 for f in sched_final.values())
    for name, ok in checks.items():
        print(f"# fig13 {name}={ok}")
    if not all(checks.values()):
        bad = [k for k, v in checks.items() if not v]
        raise SystemExit(f"fig13: geometry gates failed: {bad}")

    with open(OUT_PATH, "w") as fh:
        json.dump(bench, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {OUT_PATH}")
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
