"""Paper Fig. 7: A-DSGD bandwidth/iteration trade-off, s in {d/10,d/5,d/2}.

(a) accuracy vs iteration; (b) accuracy vs total transmitted symbols t*s.
"""
from benchmarks.common import SCALE, dataset, emit, sweep_series

TAGS = {0.1: "d10", 0.2: "d5", 0.5: "d2"}


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    res, s = sweep_series("fig7", dev, test, {"s_frac": [0.1, 0.2, 0.5]},
                          lambda r: f"a_dsgd_s{TAGS[r['s_frac']]}",
                          rows=rows, scheme="a_dsgd", k_frac=0.8, p_avg=50.0)
    summary.extend(s)
    # (b): emit symbol-count series for the same records
    d = 7850
    for rec in res.records:
        s_frac = rec["s_frac"]
        for i, acc in enumerate(rec["accs"]):
            step = min(i * SCALE.eval_every, SCALE.steps - 1)
            rows.append(f"fig7b,a_dsgd_s{TAGS[s_frac]},"
                        f"{int(step * s_frac * d)},{acc:.4f}")
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
