"""Paper Fig. 7: A-DSGD bandwidth/iteration trade-off, s in {d/10,d/5,d/2}.

(a) accuracy vs iteration; (b) accuracy vs total transmitted symbols t*s.
"""
from benchmarks.common import SCALE, dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    for s_frac, tag in ((0.1, "d10"), (0.2, "d5"), (0.5, "d2")):
        r = run_series("fig7", f"a_dsgd_s{tag}", dev, test,
                       ota("a_dsgd", s_frac=s_frac, k_frac=0.8, p_avg=50.0),
                       rows=rows)
        summary.append((f"fig7_a_dsgd_s{tag}", r["us_per_call"],
                        r["final_acc"]))
        # (b): emit symbol-count series for the same run
        accs = r["run"].accs
        d = 7850
        for i, acc in enumerate(accs):
            step = min(i * SCALE.eval_every, SCALE.steps - 1)
            rows.append(f"fig7b,a_dsgd_s{tag},{int(step * s_frac * d)},{acc:.4f}")
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
