"""CI benchmark regression gate: fail on >RATIO x slowdown vs a baseline.

Usage:
    python benchmarks/check_regression.py [--strict] BASELINE.json FRESH.json

Compares a freshly generated ``BENCH_kernels.json`` / ``BENCH_sweeps.json``
against the committed baseline and exits non-zero if any comparable timing
regressed by more than ``BENCH_REGRESSION_RATIO`` (default 2.0 — CI runners
are noisy, so the gate only catches step-change regressions, not drift).
The file kind is auto-detected: a kernels file has an ``entries`` list keyed
by (size, op, path); a sweeps file has flat ``*_us_per_round`` numbers.
Speed-ups and new entries are reported but never fail the gate; baseline
entries missing from the fresh file are *skipped with a warning* (a renamed
or retired benchmark is a review concern, not a perf regression — and a
newly landed bench file starts gating as soon as its baseline is
committed).  ``--strict`` (on in CI) additionally fails when a non-empty
baseline matches *nothing* in the fresh file: a wholesale mismatch means
the benchmark schema or naming drifted, and the gate was silently
vacuous — every timing "passed" because none was compared.
Compile-dominated timings (``UNGATED``) are excluded from gating
entirely — XLA trace+compile wall-clock varies across machines far beyond
runner noise.
"""

from __future__ import annotations

import json
import os
import sys

RATIO = float(os.environ.get("BENCH_REGRESSION_RATIO", "2.0"))


def kernel_timings(doc: dict) -> dict:
    return {(e["size"], e["op"], e["path"]): e["us_per_call"] for e in doc["entries"]}


# compile-dominated timings are machine/cache-dependent far beyond runner
# noise (XLA trace+compile wall-clock), so they are reported but never gated
UNGATED = ("compiled_cold_us_per_round",)


def sweep_timings(doc: dict) -> dict:
    return {
        k: v
        for k, v in doc.items()
        if k.endswith("_us_per_round")
        and k not in UNGATED
        and isinstance(v, (int, float))
    }


def compare(baseline: dict, fresh: dict, strict: bool = False) -> int:
    if "entries" in baseline:
        base_t, fresh_t = kernel_timings(baseline), kernel_timings(fresh)
    else:
        base_t, fresh_t = sweep_timings(baseline), sweep_timings(fresh)
    failures = 0
    matched = 0
    for key in sorted(base_t, key=str):
        if key not in fresh_t:
            print(
                f"  WARNING    {key}: in baseline, absent in fresh — "
                "skipped (retired or renamed benchmark?)"
            )
            continue
        matched += 1
        b, f = base_t[key], fresh_t[key]
        ratio = f / b if b > 0 else float("inf")
        tag = "ok"
        if ratio > RATIO:
            tag = "REGRESSION"
            failures += 1
        elif ratio < 1 / RATIO:
            tag = "speedup"
        print(f"  {tag:10s} {key}: {b:.1f} -> {f:.1f} us ({ratio:.2f}x)")
    for key in sorted(set(fresh_t) - set(base_t), key=str):
        print(f"  new        {key}: {fresh_t[key]:.1f} us (no baseline)")
    if strict and base_t and matched == 0:
        print(
            f"  STRICT     none of the {len(base_t)} baseline entr"
            f"{'y' if len(base_t) == 1 else 'ies'} matched the fresh file "
            "— the gate compared nothing (schema or naming drift?)"
        )
        failures += 1
    return failures


def main(argv) -> int:
    argv = list(argv)
    strict = "--strict" in argv
    if strict:
        argv.remove("--strict")
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as fh:
        baseline = json.load(fh)
    with open(argv[2]) as fh:
        fresh = json.load(fh)
    mode = " [strict]" if strict else ""
    print(
        f"benchmark regression gate: threshold {RATIO}x{mode} "
        f"({argv[1]} vs {argv[2]})"
    )
    failures = compare(baseline, fresh, strict=strict)
    if failures:
        print(f"FAILED: {failures} check(s) failed (threshold {RATIO}x)")
        return 1
    print("ok: no timing regressed beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
