"""Fig. 12 (beyond-paper): local-compute algorithms under non-IID data.

Accuracy per *uplink use*: with E local epochs per round every point on
the x-axis costs the same channel budget, so a local-compute algorithm
pays for extra device SGD only with device FLOPs — unless client drift
eats the gain.  The sweep crosses the local-compute axis
(``repro.local``: FedAvg-E / FedProx / FedDyn) with the MAC scheme
(A-DSGD analog, D-DSGD digital) on a Dirichlet ``beta = 0.25`` split
over M = 20 devices with B = 100 samples each — heavy label skew and
small shards, where E > 1 epochs at a drift-inducing local step size
pull each device hard toward its own skewed optimum, while the proximal
(FedProx) and dual-corrected (FedDyn) updates stay anchored to the
global model.

Each transport runs at a power budget inside its operating regime, so
the within-scheme algorithm comparison is not confounded by the MAC:

* A-DSGD is norm-adaptive (``alpha = P / (||g_tilde||^2 + 1)``, eq. 13):
  the ``+1`` is the scale slot's share of the budget, so the anchored
  algorithms' *smaller* pseudo-gradients — ``(w0 - wE) / (lr E)``
  shrinks as the anchor caps ``||w0 - wE||`` — waste power on the slot
  at the paper's P-bar and decode noisily.  ``P_AVG_ANALOG`` keeps the
  body SNR above that floor at multi-epoch delta scales.
* D-DSGD stays at the paper-scale budget: the bit-limited regime where
  drift additionally degrades through the quantizer (drifted deltas
  compress worse), which is where the digital transport actually runs.

The whole (algorithm, E, seed) grid rides the sweep engine: ``local`` is
a static axis (one compiled program per algorithm), ``local_epochs`` and
the seed replicas are vmapped — the multi-epoch scan is compiled once at
``max(E)`` and traced per point (docs/DESIGN.md §11).

Asserts (the CI smoke gates for the local-compute subsystem):

* at E = 4 epochs FedProx and FedDyn each retain strictly more accuracy
  than FedAvg-E, under BOTH the analog and the digital transport;
* every algorithm still trains (final accuracy above chance) — the axis
  composes with the MAC schemes rather than replacing them.

Timings land in ``BENCH_local.json`` (committed; gated by
check_regression.py like the other BENCH files).

Usage:
    PYTHONPATH=src python benchmarks/fig12_local.py          # figure scale
    SMOKE=1 PYTHONPATH=src python benchmarks/fig12_local.py  # CI leg
"""

import json
import os
import sys

# allow `python benchmarks/fig12_local.py` from the repo root (script mode
# puts benchmarks/ itself on sys.path, not the package's parent)
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, REPO_ROOT)

from benchmarks.common import SCALE, dataset, emit  # noqa: E402

SMOKE = bool(int(os.environ.get("SMOKE", "0")))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_local.json")

#: Dirichlet concentration: beta = 0.25 is the heavy-skew regime where
#: client drift separates the algorithms
BETA = 0.25
#: many small shards — drift needs per-device optima far from the mean
M_DEV, B_DEV = 20, 100
#: local epochs on the vmapped axis (E = 1 is the paper's device)
EPOCHS = (1, 2, 4)
#: the three multi-epoch algorithms (all share one data/seed pairing)
ALGOS = ("fedavg", "fedprox", "feddyn")
#: proximal strength / dual step — carried in the base config; each
#: algorithm reads only its own knob (fedavg reads neither)
PROX_MU = 0.5
DYN_ALPHA = 0.1
#: the drift-inducing local step size (multi-epoch full-batch GD)
LOCAL_LR = 0.6
#: analog power budget: body SNR above the scale-slot floor (docstring)
P_AVG_ANALOG = 50_000.0
#: seed replicas averaged per grid point
SEEDS = (0, 1) if SMOKE else (0, 1, 2)


def main(collect=None):
    from benchmarks.common import ota
    from repro.experiments import run_sweep

    steps = 16 if SMOKE else SCALE.steps
    dev, test = dataset(partition="dirichlet", beta=BETA, m=M_DEV, b=B_DEV)
    rows, summary, bench = [], [], {
        "smoke": SMOKE,
        "beta": BETA,
        "epochs": list(EPOCHS),
    }
    finals = {}  # (scheme, algo) -> {E: seed-averaged final accuracy}

    for scheme in ("a_dsgd", "d_dsgd"):
        base = ota(scheme, total_steps=steps, prox_mu=PROX_MU,
                   dyn_alpha=DYN_ALPHA,
                   **({"p_avg": P_AVG_ANALOG} if scheme == "a_dsgd" else {}))
        res = run_sweep(dev, test, base,
                        {"local": list(ALGOS),
                         "local_epochs": list(EPOCHS),
                         "seed": list(SEEDS)},
                        steps=steps, lr=SCALE.lr, local_lr=LOCAL_LR,
                        eval_every=SCALE.eval_every)
        for algo in ALGOS:
            finals[(scheme, algo)] = {}
            for e in EPOCHS:
                recs = [r for r in res.records
                        if r["local"] == algo and r["local_epochs"] == e]
                accs = [rec["accs"] for rec in recs]
                mean_accs = [sum(col) / len(col) for col in zip(*accs)]
                for i, acc in enumerate(mean_accs):
                    step = min(i * SCALE.eval_every, steps - 1)
                    rows.append(f"fig12,{scheme}_{algo}_E{e},{step},"
                                f"{acc:.4f}")
                finals[(scheme, algo)][e] = mean_accs[-1]
                us = sum(rec["us_per_call"] for rec in recs) / len(recs)
                name = f"fig12_{scheme}_{algo}_E{e}"
                summary.append((name, us, mean_accs[-1]))
                bench[f"{name}_us_per_round"] = round(us / steps, 1)
                bench[f"{name}_final_acc"] = round(mean_accs[-1], 4)

    emit(rows)
    e_hi = max(EPOCHS)
    for scheme in ("a_dsgd", "d_dsgd"):
        f = {a: finals[(scheme, a)] for a in ALGOS}
        print(f"# {scheme} @E={e_hi}: fedavg {f['fedavg'][e_hi]:.4f}  "
              f"fedprox {f['fedprox'][e_hi]:.4f}  "
              f"feddyn {f['feddyn'][e_hi]:.4f}  "
              f"(fedavg E=1 {f['fedavg'][1]:.4f})")

    # --- the local-compute claims this figure pins -----------------------
    checks = {}
    for scheme in ("a_dsgd", "d_dsgd"):
        f = {a: finals[(scheme, a)] for a in ALGOS}
        # drift control: the anchored algorithms strictly beat plain
        # FedAvg-E where it drifts hardest
        checks[f"{scheme}_fedprox_beats_fedavg_E{e_hi}"] = \
            f["fedprox"][e_hi] > f["fedavg"][e_hi]
        checks[f"{scheme}_feddyn_beats_fedavg_E{e_hi}"] = \
            f["feddyn"][e_hi] > f["fedavg"][e_hi]
        # composition: every algorithm still trains through this MAC
        checks[f"{scheme}_all_above_chance"] = all(
            f[a][e] > 0.15 for a in ALGOS for e in EPOCHS)
    for name, ok in checks.items():
        print(f"# fig12 {name}={ok}")
    if not all(checks.values()):
        bad = [k for k, v in checks.items() if not v]
        raise SystemExit(f"fig12: local-compute gates failed: {bad}")

    with open(OUT_PATH, "w") as fh:
        json.dump(bench, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {OUT_PATH}")
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
