"""Fig. 11 (beyond-paper): Byzantine robustness of the OTA schemes.

Sweeps the persistent Byzantine fraction (``repro.robust.faults``) against
the matching defence on each transport:

* **D-DSGD** — plain-sum aggregation vs the median-relative norm cap
  (``aggregator="norm_cap"``).  Coordinate-wise trimming is deliberately
  *not* the digital defence: D-DSGD frames are top-k sparse, the signal
  sits at the extreme ranks per coordinate, and a trim discards exactly
  that (docs/DESIGN.md §10) — the per-frame norm cap leaves sparse
  supports intact while flattening the attacker's ``byz_scale`` boost.
* **A-DSGD** — unconstrained transmitters vs the transmit-side power cap
  (``clip_power=True``).  ``make_frame`` normalises honest frames to
  ``P_t``, so an analog attacker's only leverage is violating the power
  constraint; the cap at ``power_cap * P_t`` removes that leverage and
  costs honest devices nothing (their clip scale is exactly 1.0).

The whole Byzantine grid and the seed replicas ride ONE vmapped compiled
program per (scheme, defence) combo — ``byzantine_frac`` is a
``ROBUST_VMAP_AXES`` member, and the membership draw is *nested* in the
fraction (common random numbers: a larger fraction grows the attacker set
instead of reshuffling it), so the curves are paired.

Asserts (the CI smoke gates for the robustness subsystem):

* plain A-DSGD *collapses* at >= 10% Byzantine devices while the
  power-capped run retains accuracy;
* norm-capped D-DSGD beats plain-sum D-DSGD by a margin at the highest
  swept fraction and retains most of its clean accuracy at 10%.

``SMOKE=1`` shrinks rounds/seeds for CI; ``FULL=1`` (benchmarks.common)
restores paper-scale M/B/T.
"""

import os
import sys

# allow `python benchmarks/fig11_robust.py` from the repo root (script mode
# puts benchmarks/ itself on sys.path, not the package's parent)
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import SCALE, dataset, emit  # noqa: E402

SMOKE = bool(int(os.environ.get("SMOKE", "0")))

#: Byzantine fractions on the vmapped axis (0.0 = the paired clean run)
BYZ_FRACS = (0.0, 0.1, 0.3)
#: attacker amplitude: sign_flip at this scale collapses undefended runs
BYZ_SCALE = 20.0
#: digital norm cap, in multiples of the median live-frame norm
NORM_CAP = 1.5
#: analog transmit power cap, in multiples of P_t
POWER_CAP = 1.5
#: seed replicas averaged per grid point (the Byzantine set is drawn from
#: the run-level fault key, so it is common across the seed replicas)
SEEDS = (0, 1) if SMOKE else (0, 1, 2)


def _sweep(dev, test, base, axes, steps):
    from repro.experiments import run_sweep

    return run_sweep(dev, test, base, axes, steps=steps, lr=SCALE.lr,
                     eval_every=SCALE.eval_every)


def main(collect=None):
    from benchmarks.common import ota

    steps = 16 if SMOKE else SCALE.steps
    dev, test = dataset(iid=True)
    rows, summary = [], []
    finals = {}  # series -> {frac: seed-averaged final accuracy}

    def series_rows(series, res, static_key=None, static_val=None):
        finals[series] = {}
        for frac in BYZ_FRACS:
            recs = [r for r in res.records
                    if r["byzantine_frac"] == frac
                    and (static_key is None or r[static_key] == static_val)]
            accs = [rec["accs"] for rec in recs]
            mean_accs = [sum(col) / len(col) for col in zip(*accs)]
            for i, acc in enumerate(mean_accs):
                step = min(i * SCALE.eval_every, steps - 1)
                rows.append(f"fig11,{series}_b{frac},{step},{acc:.4f}")
            finals[series][frac] = mean_accs[-1]
            us = sum(rec["us_per_call"] for rec in recs) / len(recs)
            summary.append((f"fig11_{series}_b{frac}", us, mean_accs[-1]))

    kw = dict(total_steps=steps, byz_scale=BYZ_SCALE)
    axes = {"byzantine_frac": list(BYZ_FRACS), "seed": list(SEEDS)}

    res = _sweep(dev, test, ota("d_dsgd", **kw, norm_cap=NORM_CAP),
                 {"aggregator": ["mean", "norm_cap"], **axes}, steps)
    series_rows("d_dsgd_plain", res, "aggregator", "mean")
    series_rows("d_dsgd_normcap", res, "aggregator", "norm_cap")

    res = _sweep(dev, test, ota("a_dsgd", **kw, power_cap=POWER_CAP),
                 {"clip_power": [False, True], **axes}, steps)
    series_rows("a_dsgd_plain", res, "clip_power", False)
    series_rows("a_dsgd_powercap", res, "clip_power", True)

    emit(rows)
    hi = max(BYZ_FRACS)
    a_plain, a_cap = finals["a_dsgd_plain"], finals["a_dsgd_powercap"]
    d_plain, d_cap = finals["d_dsgd_plain"], finals["d_dsgd_normcap"]
    print(f"# a_dsgd @10%: plain {a_plain[0.1]:.4f} vs powercap "
          f"{a_cap[0.1]:.4f} (clean {a_plain[0.0]:.4f})")
    print(f"# d_dsgd @{hi:.0%}: plain {d_plain[hi]:.4f} vs normcap "
          f"{d_cap[hi]:.4f} (clean {d_plain[0.0]:.4f})")

    # --- the robustness claims this figure pins --------------------------
    checks = {
        # plain analog collapses under a 10% power-boosting attacker...
        "a_dsgd_plain_collapses": a_plain[0.1] <= 0.5 * a_plain[0.0],
        # ...while the power cap retains most of the clean accuracy
        "a_dsgd_powercap_retains": a_cap[0.1] >= 0.8 * a_cap[0.0],
        "a_dsgd_powercap_beats_plain": a_cap[0.1] >= a_plain[0.1] + 0.25,
        # the digital norm cap beats the plain sum where it degrades most
        "d_dsgd_normcap_beats_plain": d_cap[hi] >= d_plain[hi] + 0.10,
        "d_dsgd_normcap_retains": d_cap[0.1] >= 0.8 * d_cap[0.0],
    }
    for name, ok in checks.items():
        print(f"# fig11 {name}={ok}")
    if not all(checks.values()):
        bad = [k for k, v in checks.items() if not v]
        raise SystemExit(f"fig11: robustness gates failed: {bad}")
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
