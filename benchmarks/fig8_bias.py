"""Fig. 8 (beyond-paper): robustness to biased data distributions.

The paper's §VI non-IID experiment (and the fading follow-up, Amiri &
Gündüz, arXiv:1907.09769) claims A-DSGD is *more robust to bias* than
D-DSGD.  This benchmark makes the bias a continuous knob: devices draw
their class proportions from Dirichlet(beta) (repro/data/partition.py),
so beta -> inf is IID and smaller beta is heavier label skew.  For each
beta both schemes run as one compiled engine grid; the summary derived
column is final accuracy, and the ``fig8_rel`` rows report accuracy
*retention* relative to the same scheme's near-IID run — A-DSGD's
retention should dominate D-DSGD's as beta decreases.
"""
from benchmarks.common import dataset, emit, sweep_series

#: near-IID anchor first; decreasing beta = increasing label skew
BETAS = (100.0, 1.0, 0.25)
SCHEMES = ("a_dsgd", "d_dsgd")


def main(collect=None):
    from repro.data.partition import label_bias

    rows, summary = [], []
    final = {}
    for beta in BETAS:
        dev, test = dataset(partition="dirichlet", beta=beta)
        bias = label_bias(dev[1])
        print(f"# beta={beta}: label bias (mean TV) = {bias:.3f}",
              flush=True)
        _, s = sweep_series(
            "fig8", dev, test, {"scheme": list(SCHEMES)},
            lambda r: f"{r['scheme']}_beta{beta}", rows=rows, p_avg=500.0)
        summary.extend(s)
        for (name, _, acc), scheme in zip(s, SCHEMES):
            final[(scheme, beta)] = acc
    # accuracy retention vs the near-IID anchor (beta = BETAS[0])
    for scheme in SCHEMES:
        for beta in BETAS:
            rel = final[(scheme, beta)] / max(final[(scheme, BETAS[0])],
                                              1e-9)
            rows.append(f"fig8_rel,{scheme},{beta},{rel:.4f}")
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
