"""Paper Fig. 3: D-DSGD power-allocation schedules (eq. 45) vs A-DSGD.

The four schedules ride ONE compiled program: ``power_schedule`` is a
vmapped sweep axis (each schedule is just a different (T,) P_t array, and
the digital bit budget q_t is host-precomputed per point and vmapped too).
"""
from benchmarks.common import dataset, emit, sweep_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    _, s = sweep_series(
        "fig3", dev, test,
        {"power_schedule": ["constant", "lh_stair", "lh_steps", "hl_steps"]},
        lambda r: f"d_dsgd_{r['power_schedule']}", rows=rows,
        scheme="d_dsgd", p_avg=200.0)
    summary.extend(s)
    _, s = sweep_series("fig3", dev, test, {"scheme": ["a_dsgd", "ideal"]},
                        lambda r: r["scheme"], rows=rows, p_avg=200.0)
    summary.extend(s)
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
