"""Paper Fig. 3: D-DSGD power-allocation schedules (eq. 45) vs A-DSGD."""
from benchmarks.common import dataset, emit, ota, run_series


def main(collect=None):
    rows, summary = [], []
    dev, test = dataset(iid=True)
    for sched in ("constant", "lh_stair", "lh_steps", "hl_steps"):
        r = run_series("fig3", f"d_dsgd_{sched}", dev, test,
                       ota("d_dsgd", p_avg=200.0, power_schedule=sched),
                       rows=rows)
        summary.append((f"fig3_d_dsgd_{sched}", r["us_per_call"],
                        r["final_acc"]))
    for scheme in ("a_dsgd", "ideal"):
        r = run_series("fig3", scheme, dev, test, ota(scheme, p_avg=200.0),
                       rows=rows)
        summary.append((f"fig3_{scheme}", r["us_per_call"], r["final_acc"]))
    emit(rows)
    if collect is not None:
        collect.extend(summary)
    return summary


if __name__ == "__main__":
    main()
