"""Benchmark entry point: one harness per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV summary at the end (derived = final
test accuracy for the figure benchmarks, dominant roofline term for the
dry-run table rows).  FULL=1 env restores paper-scale settings.
"""
from __future__ import annotations

import os
import sys
import time

# make `python benchmarks/run.py ...` work from the repo root (script mode
# puts benchmarks/ itself on sys.path, not the package's parent)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (bench_kernels, bench_llm, bench_sweeps,
                            convergence_bound,
                            fig2_schemes, fig3_power_alloc, fig4_power_sweep,
                            fig5_bandwidth, fig6_devices, fig7_s_tradeoff,
                            fig8_bias, fig9_fading, fig10_scaling,
                            fig11_robust, fig12_local, fig13_geometry,
                            roofline)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    benches = {
        "fig2": fig2_schemes.main,
        "fig3": fig3_power_alloc.main,
        "fig4": fig4_power_sweep.main,
        "fig5": fig5_bandwidth.main,
        "fig6": fig6_devices.main,
        "fig7": fig7_s_tradeoff.main,
        "fig8": fig8_bias.main,
        "fig9": fig9_fading.main,
        "fig10": fig10_scaling.main,
        "fig11": fig11_robust.main,
        "fig12": fig12_local.main,
        "fig13": fig13_geometry.main,
        "thm1": convergence_bound.main,
        "roofline": roofline.main,
        "kernels": bench_kernels.main,
        "sweeps": bench_sweeps.main,
        "llm": bench_llm.main,
    }
    summary = []
    for name, fn in benches.items():
        if only and name != only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        fn(collect=summary)
        print(f"[{name}] {time.time() - t0:.1f}s", flush=True)

    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        if isinstance(derived, float):
            print(f"{name},{us:.1f},{derived:.4f}")
        else:
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
