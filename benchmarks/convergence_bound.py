"""Theorem 1: evaluate the Pr{E_T} bound terms for the paper's setting."""
from repro.core import convergence as cv


def main(collect=None):
    import time
    t0 = time.time()
    d, s = 7850, 3925
    rows = []
    print("figure,series,T,bound")
    for k_frac in (0.5, 0.9):
        k = int(k_frac * s)
        kw = dict(d=d, k=k, s_tilde=s - 2, m=25, sigma=1.0, g_bound=1.0)
        for T in (10**4, 10**5, 10**6):
            sv = cv.sum_v_constant_power(T, p_avg=500.0, **kw)
            eta = 0.5 * cv.eta_max(T, 1.0, 1.0, 1.0, sv)
            b = (cv.theorem1_bound(T, eta=eta, c_strong=1.0, eps=1.0,
                                   g_bound=1.0, sum_v=sv, theta_star_norm=10.0)
                 if eta > 0 else float("inf"))
            rows.append((k_frac, T, b))
            print(f"thm1,k{k_frac},{T},{b:.4g}")
    dt = (time.time() - t0) * 1e6 / max(len(rows), 1)
    if collect is not None:
        collect.append(("thm1_bound", dt, rows[-1][2]))
    return rows


if __name__ == "__main__":
    main()
