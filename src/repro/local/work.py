"""Local-compute axis: what a device does between two uplink uses.

The paper's device runs exactly ONE SGD step per round and transmits its
gradient.  Deployed federated systems amortise each expensive uplink over
``E`` local epochs, with drift correction against the client-drift bias
that multi-epoch local work introduces under non-IID shards (FedProx's
proximal term, FedDyn's dynamic regulariser).  This module makes that
choice an axis *orthogonal* to the MAC scheme: a :class:`LocalWork`
produces the per-device model delta that feeds the existing
error-feedback + top-k + projection pipeline, so every registered scheme
composes with every registered algorithm and the scheme encode/decode
contract is untouched.

Registered algorithms::

    sgd      E plain SGD steps, transmit the mean gradient (E=1 — the
             default — is *bitwise* the legacy single-gradient round)
    fedavg   FedAvg-E: E local epochs, transmit (w0 - wE) / (lr E)
    fedprox  FedAvg-E with the proximal term (mu/2)||w - w0||^2
    feddyn   FedAvg-E with a per-device dual (dynamic regulariser); the
             dual is persistent state — the dense engine carries it in the
             scan, the population engine banks it in a ``BankedState``

Traced-vs-static split (docs/DESIGN.md §11): ``local`` selects program
structure and stays static; ``local_epochs`` / ``prox_mu`` / ``dyn_alpha``
are traced per-round scalars (``LOCAL_OVERRIDE_ATTRS``), swapped per grid
point via :meth:`LocalWork.with_overrides` exactly like
``Scheme.with_overrides`` — so a whole (E, mu, alpha) grid rides one
vmapped program.  The epoch loop is a ``lax.scan`` of *static* length
``max_epochs`` (the grid maximum) with a traced ``e < local_epochs``
cutoff; epochs past the cutoff leave the carry untouched, so a grid point
at E < max_epochs is bitwise the exact-length loop.
"""

from __future__ import annotations

import copy
from typing import Dict, Type

import jax
import jax.flatten_util
import jax.numpy as jnp

LOCAL_REGISTRY: Dict[str, Type["LocalWork"]] = {}

#: LocalWork attributes that ride the vmapped override path (the sweep
#: engine's ``LOCAL_VMAP_AXES``)
LOCAL_OVERRIDE_ATTRS = ("local_epochs", "prox_mu", "dyn_alpha")


def register_local(name: str):
    """Class decorator: register a :class:`LocalWork` under ``name``."""

    def deco(cls):
        cls.name = name
        LOCAL_REGISTRY[name] = cls
        return cls

    return deco


def get_local(cfg, local_lr: float = 0.1) -> "LocalWork":
    """Resolve ``cfg.local`` against the registry."""
    try:
        cls = LOCAL_REGISTRY[cfg.local]
    except KeyError:
        raise KeyError(
            f"unknown local algorithm {cfg.local!r}; "
            f"known: {sorted(LOCAL_REGISTRY)}"
        ) from None
    return cls(cfg, local_lr)


class LocalWork:
    """Contract for the device-side inner loop.

    Hooks (all on flat ``(d,)`` vectors of ONE device; ``w0`` is the round's
    global model, ``w`` the local iterate):

    * :meth:`init_dual` — per-device persistent dual state, or ``None``
    * :meth:`inner_grad` — descent direction at ``w`` given the data
      gradient ``g`` (the ``inner_step`` of the contract: the driver applies
      ``w -= lr * inner_grad(...)``)
    * :meth:`delta_out` — the transmitted pseudo-gradient after E epochs
    * :meth:`dual_out` — the dual update after E epochs

    ``max_epochs`` is the *static* scan length (sweeps bump it to the grid
    maximum before tracing, the ``q_max`` pattern); ``local_epochs`` is the
    *traced* epoch count — values above ``max_epochs`` truncate.
    """

    name = "?"
    #: static: this algorithm carries a per-device dual vector
    has_dual = False

    def __init__(self, cfg, local_lr: float = 0.1):
        self.cfg = cfg
        self.lr = float(local_lr)
        self.max_epochs = max(int(cfg.local_epochs), 1)
        # traced per-round scalars — vmappable via with_overrides
        self.local_epochs = jnp.float32(cfg.local_epochs)
        self.prox_mu = jnp.float32(cfg.prox_mu)
        self.dyn_alpha = jnp.float32(cfg.dyn_alpha)

    @property
    def identity(self) -> bool:
        """Static: configured as the legacy one-gradient-per-round device.

        When true the engines keep their original ``device_grads`` path —
        the *same jaxpr* as before this axis existed, which is what pins
        ``local=sgd, local_epochs=1`` bitwise to every committed golden.
        """
        return False

    def with_overrides(self, **attrs) -> "LocalWork":
        """Shallow copy with traced knobs replaced (the sweep hook)."""
        new = copy.copy(self)
        for name, value in attrs.items():
            if name not in LOCAL_OVERRIDE_ATTRS:
                raise AttributeError(
                    f"unknown local override {name!r}; traced knobs: "
                    f"{LOCAL_OVERRIDE_ATTRS}"
                )
            setattr(new, name, value)
        return new

    def init_dual(self, m: int, d: int):
        """(m, d) initial duals, or ``None`` for dual-free algorithms."""
        return jnp.zeros((m, d), jnp.float32) if self.has_dual else None

    # ----------------------------------------------------- per-epoch hooks
    def inner_grad(self, g, w, w0, dual):
        """Descent direction at the local iterate ``w``."""
        return g

    def delta_out(self, w0, w_end, g_sum, n_eff):
        """The transmitted pseudo-gradient (the paper's delta convention:
        ``flat_local_delta`` transmits ``(w0 - wJ) / (lr J)``)."""
        return (w0 - w_end) / (self.lr * n_eff)

    def dual_out(self, dual, w0, w_end):
        """Updated dual after the epoch loop (dual-free: pass-through)."""
        return dual


@register_local("sgd")
class SGDLocal(LocalWork):
    """The paper's device, generalised: E plain SGD steps, transmit the
    mean of the local gradients.  At E=1 the mean is ``g / 1.0 == g``
    bitwise (IEEE-754: division by one is exact), unlike the
    iterate-difference form which rounds through a multiply-subtract."""

    @property
    def identity(self) -> bool:
        return self.max_epochs == 1

    def delta_out(self, w0, w_end, g_sum, n_eff):
        return g_sum / n_eff


@register_local("fedavg")
class FedAvgLocal(LocalWork):
    """FedAvg-E: E local epochs over the device shard, transmit the model
    delta rescaled to gradient units, ``(w0 - wE) / (lr E)``."""


@register_local("fedprox")
class FedProxLocal(LocalWork):
    """FedProx: each inner step descends the proximal objective
    ``f(w) + (mu/2) ||w - w0||^2``.  At ``mu=0`` the added term is
    ``0 * (w - w0)`` — exactly zero — so fedprox(mu=0) == fedavg."""

    def inner_grad(self, g, w, w0, dual):
        return g + self.prox_mu * (w - w0)


@register_local("feddyn")
class FedDynLocal(LocalWork):
    """FedDyn: dynamic regularisation with a per-device dual.

    Inner objective ``f(w) - <dual, w> + (alpha/2)||w - w0||^2``; after the
    epoch loop the dual absorbs the realised drift,
    ``dual' = dual - alpha (wE - w0)``.  With zero gradients the iterate
    never moves and the update telescopes to zero — a fresh (cold-read)
    device with ``dual = 0`` behaves exactly like FedAvg-E until it drifts,
    which is why the population engine can bank duals in a direct-mapped
    ``BankedState`` whose cold slots read zero (docs/DESIGN.md §11).
    """

    has_dual = True

    def inner_grad(self, g, w, w0, dual):
        return g + self.dyn_alpha * (w - w0) - dual

    def dual_out(self, dual, w0, w_end):
        return dual - self.dyn_alpha * (w_end - w0)


def local_device_grads(
    lw: LocalWork,
    grad_fn,
    params,
    xd,
    yd,
    momenta,
    duals=None,
    *,
    momentum_correction: float = 0.0,
):
    """(M, d) transmitted deltas + updated ``(momenta, duals)``.

    The multi-epoch generalisation of
    :func:`repro.train.paper_repro.device_grads` — the engines call one or
    the other based on the static :attr:`LocalWork.identity` gate.
    ``grad_fn(w_flat, xm, ym) -> (d,)`` is the model's flat-gradient
    closure (``repro.train.paper_repro.flat_grad_fn``), injected so this
    module stays model-agnostic.  The per-device epoch loop is a
    ``lax.scan`` of static length ``lw.max_epochs`` with a traced
    ``e < local_epochs`` cutoff: discarded epochs leave the carry
    untouched bitwise, so vmapped ``local_epochs`` grids share one trace.
    """
    w0 = jax.flatten_util.ravel_pytree(params)[0]
    n_eff = jnp.maximum(lw.local_epochs, 1.0)

    def one_device(xm, ym, dual):
        def body(carry, e):
            w, g_sum = carry
            g = grad_fn(w, xm, ym)
            dvec = lw.inner_grad(g, w, w0, dual)
            live = e.astype(jnp.float32) < lw.local_epochs
            w = jnp.where(live, w - lw.lr * dvec, w)
            g_sum = jnp.where(live, g_sum + dvec, g_sum)
            return (w, g_sum), None

        (w_end, g_sum), _ = jax.lax.scan(
            body, (w0, jnp.zeros_like(w0)), jnp.arange(lw.max_epochs)
        )
        return lw.delta_out(w0, w_end, g_sum, n_eff), lw.dual_out(dual, w0, w_end)

    deltas, new_duals = jax.vmap(
        one_device, in_axes=(0, 0, 0 if lw.has_dual else None)
    )(xd, yd, duals if lw.has_dual else None)
    if momentum_correction > 0:
        momenta = momentum_correction * momenta + deltas
        deltas = momenta
    return deltas, momenta, new_duals
