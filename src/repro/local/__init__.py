"""repro.local: the local-compute axis (FedAvg-E / FedProx / FedDyn).

What each device does between two uplink uses, as an axis orthogonal to
the MAC scheme registry — see :mod:`repro.local.work` and
docs/DESIGN.md §11.
"""

from repro.local.work import (  # noqa: F401
    LOCAL_OVERRIDE_ATTRS,
    LOCAL_REGISTRY,
    LocalWork,
    get_local,
    local_device_grads,
    register_local,
)
