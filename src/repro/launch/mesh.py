"""Production meshes.  A function (never module-level) so importing this file
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.

Target: TPU v5e, 16x16 = 256 chips per pod; 2 pods multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~per-chip usable estimate)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB
