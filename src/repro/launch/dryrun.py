import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
with ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis,
parse collective bytes from the compiled HLO, and save JSON for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only] ...

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — hence the unusual import order.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, INPUT_SHAPES, ArchConfig, OTAConfig,  # noqa: E402
                           ShapeConfig, TrainConfig, get_config,
                           ota_overrides, approx_param_count,
                           active_param_count)
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.train import serve as serve_lib  # noqa: E402
from repro.train import trainer as trainer_lib  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# shapes whose decode needs a sliding window (sub-quadratic rule, docs/DESIGN.md §5)
LONG_WINDOW = 8192
SKIPS = {
    # (arch, shape): reason — recorded, not silently dropped
    ("whisper_base", "long_500k"):
        "enc-dec with <=448-token decoder context; 500k decode is void",
}


def decode_window_for(arch: ArchConfig, shape: ShapeConfig) -> Optional[int]:
    if shape.name != "long_500k":
        return None
    if arch.family in ("ssm",):
        return None                       # no KV cache at all
    return LONG_WINDOW                    # dense/moe/vlm/hybrid: SWA variant


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                train_cfg: Optional[TrainConfig] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, L = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train" or shape.kind == "prefill":
        n_text = L - (arch.n_vision_tokens if arch.family == "vlm" else 0)
        batch = {"tokens": sds((B, n_text), jnp.int32)}
        if arch.family == "vlm":
            batch["extra"] = sds((B, arch.n_vision_tokens, arch.d_model),
                                 jnp.bfloat16)
            batch["positions"] = sds((B, L, 3), jnp.int32)
        if arch.encoder is not None:
            e = arch.encoder
            batch["frames"] = sds((B, e.n_frames, e.d_model), jnp.bfloat16)
        return batch
    # decode: one new token + cache handled separately
    return {"tokens": sds((B, 1), jnp.int32)}


def _collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes of collective ops in compiled HLO."""
    ops = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
           "collective-permute")
    dtype_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                   "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                   "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    out = {k: 0.0 for k in ops}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        opname = None
        for op in ops:
            token = rhs.strip()
            # result types precede the op name in HLO: "f32[...] all-reduce("
            idx = token.find(op + "(")
            if idx == -1:
                idx = token.find(op + "-start(")
            if idx != -1:
                opname = op
                typestr = token[:idx]
                break
        if opname is None or (opname + "-done") in rhs:
            continue
        nbytes = 0.0
        for dt, dims in shape_re.findall(typestr):
            if dt not in dtype_bytes:
                continue
            n = 1
            for dim in dims.split(","):
                if dim:
                    n *= int(dim)
            nbytes += n * dtype_bytes[dt]
        out[opname] += nbytes
    out["total"] = sum(out[k] for k in ops)
    return out


def analyze(compiled, lowered=None) -> Dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = _collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "mem_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "collective_bytes": coll,
    }


def dryrun_one(arch_id: str, shape_id: str, multi_pod: bool,
               aggregator: str = "a_dsgd", ota_axes=None,
               variant: str = "baseline",
               ota_kw: Optional[dict] = None) -> Dict:
    arch = get_config(arch_id)
    shape = INPUT_SHAPES[shape_id]
    if (arch_id, shape_id) in SKIPS:
        return {"skipped": SKIPS[(arch_id, shape_id)]}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()

    if shape.kind == "train":
        train_cfg = TrainConfig(compute_dtype="bfloat16", remat=True,
                                total_steps=1000)
        ota = ota_overrides(arch_id)
        kw = dict(scheme=aggregator)
        if ota_kw:
            kw.update(ota_kw)
        import dataclasses as _dc
        ota = _dc.replace(ota, **kw)
        if ota_axes is None:
            ota_axes = ("pod", "data") if multi_pod else ("data",)
        if ota.layout == "sliced":
            ts = trainer_lib.make_train_step_sliced(
                arch, train_cfg, ota, mesh, ota_axes=ota_axes, donate=True)
        else:
            ts = trainer_lib.make_train_step(arch, train_cfg, ota, mesh,
                                             ota_axes=ota_axes, donate=True)
        batch = input_specs(arch, shape, train_cfg)
        jfn = ts.jitted(batch)
        aparams = trainer_lib.abstract_params(arch)
        opt_abstract = jax.eval_shape(
            lambda p: trainer_lib.make_optimizer(train_cfg).init(p), aparams)
        sdt = jnp.dtype(ota.state_dtype)
        if ota.layout == "sliced":
            sh_shape, rep_shape = ts.delta_shape
            delta = {"sh": jax.ShapeDtypeStruct(sh_shape, sdt),
                     "rep": jax.ShapeDtypeStruct(rep_shape, sdt)}
        else:
            delta = jax.ShapeDtypeStruct(ts.delta_shape, sdt)
        lowered = jfn.lower(aparams, opt_abstract, delta, batch,
                            jax.ShapeDtypeStruct((), jnp.int32),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        compiled = lowered.compile()
        info = analyze(compiled)
        info["d"] = ts.d
        info["d_pad"] = ts.d_pad
        info["m_devices"] = ts.m_devices
    elif shape.kind == "prefill":
        # prefill = forward filling a fresh KV cache, auto sharding
        ss = serve_lib.make_serve_step(arch, mesh, shape.global_batch,
                                       shape.seq_len)
        batch = input_specs(arch, shape)
        aparams = trainer_lib.abstract_params(arch)
        acache = jax.eval_shape(
            lambda: model_lib.init_decode_cache(arch, shape.global_batch,
                                                shape.seq_len, jnp.bfloat16))

        def prefill(params, cache, batch):
            from repro.models import transformer
            enc_out = None
            if arch.encoder is not None:
                enc_out = transformer.encode_audio(
                    params, arch, batch["frames"].astype(jnp.bfloat16))
            logits, new_cache, _ = transformer.forward(
                params, arch, batch["tokens"],
                positions=batch.get("positions"),
                extra_embeds=batch.get("extra"),
                enc_out=enc_out, cache=cache, cache_index=0,
                compute_dtype=jnp.bfloat16, remat=False)
            return logits[:, -1:], new_cache

        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        bspec = NamedSharding(mesh, P(data_axes))
        jfn = jax.jit(prefill,
                      in_shardings=(ss.param_sharding, ss.cache_sharding,
                                    jax.tree.map(lambda _: bspec, batch)),
                      out_shardings=(None, ss.cache_sharding),
                      donate_argnums=(1,))
        lowered = jfn.lower(aparams, acache, batch)
        compiled = lowered.compile()
        info = analyze(compiled)
    else:  # decode
        window = decode_window_for(arch, shape)
        ss = serve_lib.make_serve_step(arch, mesh, shape.global_batch,
                                       shape.seq_len, decode_window=window)
        aparams = trainer_lib.abstract_params(arch)
        acache = jax.eval_shape(lambda: ss.init_cache())
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        args = [aparams, acache, tok, jax.ShapeDtypeStruct((), jnp.int32)]
        if arch.encoder is not None:
            args.append(jax.ShapeDtypeStruct(
                (shape.global_batch, arch.encoder.n_frames,
                 arch.encoder.d_model), jnp.bfloat16))
        lowered = ss.decode_fn.lower(*args)
        compiled = lowered.compile()
        info = analyze(compiled)
        info["decode_window"] = window

    info.update({
        "arch": arch_id, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "aggregator": aggregator if shape.kind == "train" else None,
        "variant": variant,
        "kind": shape.kind,
        "compile_seconds": round(time.time() - t0, 1),
        "model_params": approx_param_count(arch),
        "active_params": active_param_count(arch),
    })
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--aggregator", default="a_dsgd")
    ap.add_argument("--ota-axes", default=None,
                    help="comma list, e.g. 'pod' for the site_ota variant")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ota", default=None,
                    help='JSON OTAConfig overrides, e.g. '
                         '\'{"layout":"sliced","frame_dtype":"bfloat16"}\'')
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    outdir = args.out or os.path.abspath(RESULTS_DIR)
    os.makedirs(outdir, exist_ok=True)
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    ota_axes = tuple(args.ota_axes.split(",")) if args.ota_axes else None

    n_ok = n_fail = 0
    for arch_id in archs:
        for shape_id in shapes:
            for mp in meshes:
                tag = f"{arch_id}__{shape_id}__{'mp' if mp else 'sp'}__" \
                      f"{args.aggregator}__{args.variant}"
                path = os.path.join(outdir, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    info = dryrun_one(arch_id, shape_id, mp,
                                      aggregator=args.aggregator,
                                      ota_axes=ota_axes,
                                      variant=args.variant,
                                      ota_kw=json.loads(args.ota)
                                      if args.ota else None)
                    with open(path, "w") as f:
                        json.dump(info, f, indent=1)
                    if "skipped" in info:
                        print(f"  -> SKIP ({info['skipped']})")
                    else:
                        print(f"  -> ok flops={info['flops']:.3e} "
                              f"coll={info['collective_bytes']['total']:.3e}B "
                              f"({info['compile_seconds']}s)")
                    n_ok += 1
                except Exception as e:   # noqa: BLE001
                    n_fail += 1
                    print(f"  -> FAIL {type(e).__name__}: {e}")
                    traceback.print_exc()
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
