"""End-to-end training driver.

Selects an architecture config (full or --reduced), builds the mesh, the OTA
aggregator, the token pipeline, and runs the distributed train step for
--steps steps with periodic checkpointing and metrics.

CPU-sized example (the container has one core; the production mesh path is
exercised by launch/dryrun.py):

  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --reduced \
      --devices 8 --mesh 4x2 --steps 200 --aggregator a_dsgd

On a real TPU slice drop --reduced/--devices and pass --mesh 16x16.
"""
import argparse
import os
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU simulation)")
    ap.add_argument("--mesh", default="4x2", help="DxM or PxDxM")
    ap.add_argument("--aggregator", default="a_dsgd",
                    choices=["ideal", "a_dsgd"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--p-avg", type=float, default=500.0)
    ap.add_argument("--s-frac", type=float, default=0.25)
    ap.add_argument("--block-size", type=int, default=512)
    ap.add_argument("--site-ota", action="store_true",
                    help="ota_axes=('pod',): edge sites = pods")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import OTAConfig, TrainConfig
    from repro.data.synthetic import TokenStream
    from repro.train.checkpoint import save_checkpoint
    from repro.train.trainer import make_train_step

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(tuple(dims), names)
    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    train_cfg = TrainConfig(optimizer="adam", lr=args.lr, warmup_steps=10,
                            total_steps=args.steps,
                            compute_dtype="float32" if args.reduced
                            else "bfloat16", remat=True)
    ota = OTAConfig(scheme=args.aggregator, projection="blocked",
                    block_size=args.block_size, s_frac=args.s_frac,
                    k_frac=0.5, rademacher=True, p_avg=args.p_avg,
                    total_steps=args.steps, amp_iters=10,
                    mean_removal_steps=10)
    ota_axes = (("pod",) if args.site_ota and "pod" in names
                else tuple(a for a in names if a in ("pod", "data")))
    ts = make_train_step(arch, train_cfg, ota, mesh, ota_axes=ota_axes)
    print(f"[train] arch={arch.name} d={ts.d:,} M={ts.m_devices} "
          f"mesh={dict(zip(names, dims))} ota_axes={ota_axes}", flush=True)

    params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
    stream = TokenStream(vocab=arch.vocab, seq_len=args.seq,
                         batch=args.batch, seed=0)
    jfn = ts.jitted({"tokens": jnp.zeros((args.batch, args.seq), jnp.int32)})
    t0 = time.time()
    for step in range(args.steps):
        batch = {"tokens": jnp.asarray(stream.batch_at(step)["tokens"])}
        params, opt_state, delta, met = jfn(params, opt_state, delta, batch,
                                            jnp.asarray(step),
                                            jax.random.PRNGKey(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(met['global_loss']):.4f}  "
                  f"ppl {float(met['ppl']):.1f}  "
                  f"{(time.time() - t0) / (step + 1):.2f}s/step", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt_state},
                        step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
