"""Straggler model: per-device compute latency with a deadline cutoff.

Device m's round latency is ``speed_m * Exp(1)`` — a static lognormal
slowdown factor (drawn once per run, heavy-tailed across the population)
times a per-round exponential draw (contention/jitter).  Devices that miss
``straggler_deadline`` are dropped from the cohort mask, so they silently
fall out of the MAC sum exactly like deep-faded devices (their error state
keeps the round's update; see ``round_masked``).

The deadline enters as a traced compare, so it is a vmappable sweep axis;
at the default ``inf`` every finite latency passes — the compare is always
true, preserving the K == M bitwise parity path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_speed(key: jnp.ndarray, m: int, speed_sigma: float) -> jnp.ndarray:
    """(M,) lognormal slowdown factors; sigma = 0 means all-equal (1.0)."""
    if speed_sigma <= 0:
        return jnp.ones((m,))
    return jnp.exp(speed_sigma * jax.random.normal(key, (m,)))


def latencies(key: jnp.ndarray, speed: jnp.ndarray) -> jnp.ndarray:
    """Per-round compute latencies for the given (cohort) speed factors."""
    return speed * jax.random.exponential(key, speed.shape)


def deadline_mask(lat: jnp.ndarray, deadline) -> jnp.ndarray:
    """(K,) bool: which devices finished before the (traced) deadline."""
    return lat <= jnp.asarray(deadline, lat.dtype)
