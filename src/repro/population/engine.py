"""Sampled-cohort round engine: federated runs over M-large populations.

One round = draw availability -> sample a K-cohort -> gather banked error
state and cohort data -> run the existing scheme encode/MAC/decode on the
K rows only (via :func:`repro.experiments.engine.round_masked` with
injected cohort-indexed device keys and channel draw) -> scatter the
updated accumulators back.  The whole federated run is one ``jit(lax.scan)``
— the scan carry is ``(params, opt_state, banks)``; per-round temporaries
are O(K * d) plus O(M) scalars (keys/scores/masks), never O(M * d).

RNG layout: round t of seed 0 uses ``PRNGKey(1000 + t)`` (the engine's key
stream), salted per consumer — 0 MAC AWGN, 1 device encode, 2 channel draw
(shared with the dense drivers), plus the population's own salts
3 availability, 4 cohort sampling, 5 straggler latency (6 is the fault
trace, shared with the dense drivers — repro.robust.faults).  Device m's
encode
key is row m of ``split(fold_in(key, 1), M)`` and its channel row comes
from the full-M draw (:meth:`Scheme.cohort_channel_draw`), so a K == M
cohort with no churn/stragglers reproduces ``round_simulated`` /
``run_compiled`` bitwise — pinned by the ``population_full`` golden.

Traced per-round knobs (``avail_rate``, ``straggler_deadline``,
``k_active``, ``site_noise_scale``, ``backhaul_sigma2``) live as
attributes on :class:`CompiledPopulation` and are swapped per grid point
via :meth:`CompiledPopulation.with_overrides` — the same contract as
``Scheme.with_overrides`` — which is how
:func:`repro.experiments.sweep.run_population_sweep` vmaps whole grids
over them.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core import scheduling
from repro.core.schemes import MACContext, Scheme, get_scheme
from repro.data.partition import PopulationPartition
from repro.experiments.engine import (
    EngineRun, _subsample, round_keys, round_masked, run_checkpointed,
)
from repro.local.work import (
    LOCAL_OVERRIDE_ATTRS, LocalWork, get_local, local_device_grads,
)
from repro.optim.optim import Optimizer
from repro.robust import faults, guards
from repro.population import churn, stragglers
from repro.population.hierarchy import site_mac_sum
from repro.population.sampler import sample_cohort
from repro.population.state import (
    BankedState, PopulationConfig, gather_cohort, init_banks,
    init_population, scatter_cohort,
)
from repro.train.paper_repro import (
    accuracy, ce_loss, device_grads, flat_grad_fn, init_linear,
)

#: round-key salts owned by the population layer (0/1/2 belong to the MAC /
#: encode / channel-draw consumers, matching round_simulated)
SALT_AVAIL, SALT_SAMPLE, SALT_LATENCY = 3, 4, 5

#: CompiledPopulation attributes that ride the vmapped override path
POP_OVERRIDE_ATTRS = (
    "avail_rate",
    "straggler_deadline",
    "k_active",
    "site_noise_scale",
    "backhaul_sigma2",
)


class PopulationData:
    """Training data addressable by cohort.

    Two layouts behind one ``cohort_batch`` view: dense per-device tensors
    ``(M, B, dim)`` (small M — the legacy layout, used by the parity
    tests), or a sample pool ``(N, dim)`` plus a
    :class:`~repro.data.partition.PopulationPartition` whose arithmetic
    shard addressing materialises only the cohort's ``(K, B)`` rows inside
    the trace (large M — nothing (M, B)-sized ever exists).
    """

    def __init__(self, m, b, dim, n_classes, *, xd=None, yd=None, x=None,
                 y=None, part: Optional[PopulationPartition] = None):
        self.m, self.b, self.dim, self.n_classes = m, b, dim, n_classes
        self.xd, self.yd = xd, yd
        self.x, self.y, self.part = x, y, part

    @classmethod
    def from_dense(cls, x_dev, y_dev) -> "PopulationData":
        m, b, dim = x_dev.shape
        return cls(m, b, dim, int(np.max(y_dev)) + 1,
                   xd=jnp.asarray(x_dev), yd=jnp.asarray(y_dev))

    @classmethod
    def from_pool(cls, x, y, part: PopulationPartition) -> "PopulationData":
        if len(y) != part.n:
            raise ValueError(
                f"pool has {len(y)} samples, partition expects {part.n}")
        return cls(part.m, part.b, x.shape[-1], int(np.max(y)) + 1,
                   x=jnp.asarray(x), y=jnp.asarray(y), part=part)

    def cohort_batch(self, cohort: jnp.ndarray):
        """(K, B, dim), (K, B) batches of the cohort's devices (traced)."""
        if self.xd is not None:
            return self.xd[cohort], self.yd[cohort]
        idx = self.part.sample_indices(cohort)
        return self.x[idx], self.y[idx]


def population_round(scheme: Scheme, banks: BankedState, cohort: jnp.ndarray,
                     mask: jnp.ndarray, grads: jnp.ndarray, step,
                     key: jnp.ndarray, ctx: MACContext, m_total: int, *,
                     gains=None, sites=None, n_sites: int = 1,
                     site_noise_scale=1.0, backhaul_sigma2=0.0,
                     site_trim_frac: float = 0.0, draw=None, sched=None):
    """One sampled-cohort aggregation round.

    cohort: (K,) sorted device ids; mask: (K,) 0/1 participation (churn,
    stragglers, k_active already folded in); grads: (K, d) cohort
    gradients.  ``gains``/``sites`` are the cohort rows of the population's
    large-scale gain / edge-site arrays.  Returns
    ``(ghat, new_banks, metrics)``.

    The round is :func:`round_masked` with cohort-addressed injections:
    device keys are the cohort rows of the full-M key split, the channel
    draw is the cohort view of the full-M realisation, large-scale gains
    multiply the received-power factor, and (for n_sites > 1) the MAC is
    the hierarchical two-stage sum.  All injections degrade bitwise to the
    dense driver at K == M with the defaults (identity gather, gains 1.0,
    flat MAC).

    ``draw`` / ``sched`` pre-empt the channel realisation and inject a
    subband-scheduler transmit set (``CompiledPopulation`` evaluates the
    cohort draw itself so the scheduler can rank the round's gains; a
    caller-supplied ``draw`` must already include any large-scale
    ``gains`` composition — the in-place multiply is skipped).
    """
    deltas = gather_cohort(banks, cohort)
    dev_keys = jax.random.split(jax.random.fold_in(key, 1), m_total)[cohort]
    if draw is None:
        draw = scheme.cohort_channel_draw(jax.random.fold_in(key, 2), step,
                                          cohort, m_total, mask=mask > 0)
        if gains is not None:
            draw = draw._replace(p_factor=draw.p_factor * gains)
    fault = None
    if scheme.robust_on:
        # the cohort's rows of the full-population fault trace — a K < M
        # cohort sees exactly the faults the full simulation would have
        # dealt those devices (matching the channel-draw contract)
        fault = scheme.cohort_fault_draw(
            jax.random.fold_in(key, faults.SALT_FAULT), step, cohort,
            m_total)
    mac = None
    if n_sites > 1:
        if sites is None:
            raise ValueError("n_sites > 1 needs the cohort's site ids")

        def mac(frames, mac_key, sigma2):
            return site_mac_sum(frames, sites, n_sites, mac_key, sigma2,
                                site_noise_scale=site_noise_scale,
                                backhaul_sigma2=backhaul_sigma2,
                                site_trim_frac=site_trim_frac)

    ghat, new_deltas, metrics = round_masked(scheme, grads, deltas, step,
                                             key, mask, ctx,
                                             dev_keys=dev_keys, draw=draw,
                                             mac=mac, fault=fault,
                                             sched=sched)
    banks = scatter_cohort(banks, cohort, new_deltas)
    metrics["cohort_frac"] = jnp.sum(mask) / cohort.shape[0]
    return ghat, banks, metrics


@dataclass(frozen=True)
class PopulationExperiment:
    """Static description of one population training configuration."""
    cfg: OTAConfig
    pop: PopulationConfig
    steps: int
    lr: float = 1e-3
    eval_every: int = 10
    optimizer: str = "adam"
    local_steps: int = 1
    local_lr: float = 0.1
    seed: int = 0
    use_kernel: bool = False
    guard: Optional[guards.GuardConfig] = None


class CompiledPopulation:
    """Compile-once runner: one population configuration, one scan.

    :meth:`run` is a pure traced function — ``jit``/``vmap`` it freely.
    ``overrides`` splits between the scheme (``p_sched``/``q_sched`` and
    the channel scalars, via ``Scheme.with_overrides``) and the runner's
    own traced knobs (``POP_OVERRIDE_ATTRS``, via :meth:`with_overrides`).
    """

    def __init__(self, data: PopulationData, x_test, y_test,
                 exp: PopulationExperiment):
        pop = exp.pop
        if data.m != pop.m_total:
            raise ValueError(
                f"data addresses {data.m} devices, population has "
                f"{pop.m_total}")
        self.exp = exp
        self.data = data
        params = init_linear(data.dim, data.n_classes,
                             jax.random.PRNGKey(exp.seed))
        flat0, self.unravel = jax.flatten_util.ravel_pytree(params)
        self.d = flat0.shape[0]
        self.params0 = params
        self.scheme = get_scheme(exp.cfg, self.d, pop.k_cohort)
        self.localwork = get_local(exp.cfg, exp.local_lr)
        if not self.localwork.identity and exp.local_steps > 1:
            raise ValueError(
                "local_steps > 1 (the legacy FedAvg path) conflicts with "
                f"the configured local algorithm {exp.cfg.local!r} at "
                f"local_epochs={exp.cfg.local_epochs}; use cfg.local_epochs")
        self._grad_fn = flat_grad_fn(self.unravel)
        self.opt = Optimizer(name=exp.optimizer, lr=exp.lr)
        self.xt, self.yt = jnp.asarray(x_test), jnp.asarray(y_test)
        self.ctx = MACContext(
            m=pop.k_cohort, fading=exp.cfg.fading, csi=self.scheme.csi,
            use_kernel=exp.use_kernel or exp.cfg.use_kernel)
        self.pstate0 = init_population(
            pop, self.d, exp.steps, dtype=jnp.dtype(exp.cfg.state_dtype))
        # FedDyn duals are persistent per-device state, banked exactly like
        # the error accumulators — a cold slot reads dual = 0, which IS the
        # algorithm's fresh-device initialisation, so direct-mapped eviction
        # degrades a device to "fresh", never to "wrong" (DESIGN.md §11).
        # Kept float32 regardless of state_dtype: duals integrate alpha-
        # scaled drift and are never renormalised by error feedback.
        self.dual_banks0 = None
        if self.localwork.has_dual:
            cap = pop.capacity if pop.capacity else pop.m_total
            self.dual_banks0 = init_banks(cap, min(pop.bank_size, cap),
                                          self.d, jnp.float32)
        # proportional-fair average-rate state: one scalar per device,
        # banked exactly like the duals (cold slot reads 0 == fresh device)
        self.scheduler = scheduling.get_scheduler(exp.cfg)
        self.sched_banks0 = None
        if self._sched_state:
            cap = pop.capacity if pop.capacity else pop.m_total
            self.sched_banks0 = init_banks(cap, min(pop.bank_size, cap),
                                           1, jnp.float32)
        # traced per-round knobs — vmappable via with_overrides
        self.avail_rate = jnp.float32(pop.avail_rate)
        self.straggler_deadline = jnp.float32(pop.straggler_deadline)
        self.k_active = jnp.float32(pop.k_cohort)
        self.site_noise_scale = jnp.float32(pop.site_noise_scale)
        self.backhaul_sigma2 = jnp.float32(pop.backhaul_sigma2)

    def with_overrides(self, **attrs) -> "CompiledPopulation":
        """Shallow copy with traced knobs replaced (the sweep hook)."""
        new = copy.copy(self)
        for name, value in attrs.items():
            if name not in POP_OVERRIDE_ATTRS:
                raise AttributeError(
                    f"unknown population override {name!r}; traced knobs: "
                    f"{POP_OVERRIDE_ATTRS}")
            setattr(new, name, value)
        return new

    # ------------------------------------------------------------- pieces
    @property
    def _sched_state(self) -> bool:
        return self.scheduler is not None and self.scheduler.has_state

    def _carry0(self):
        carry = (self.params0, self.opt.init(self.params0),
                 self.pstate0.banks)
        if self.localwork.has_dual:
            carry = carry + (self.dual_banks0,)
        if self._sched_state:
            carry = carry + (self.sched_banks0,)
        if self.exp.guard is not None:
            carry = carry + (guards.init_guard_state(),)
        return carry

    def _round(self, sch: Scheme, lw: LocalWork, carry, t, key):
        params, opt_state, banks = carry[:3]
        dual_banks = carry[3] if lw.has_dual else None
        sched_banks = (carry[3 + int(lw.has_dual)] if self._sched_state
                       else None)
        gstate = carry[-1] if self.exp.guard is not None else None
        old_extras = ((banks,) + ((dual_banks,) if lw.has_dual else ())
                      + ((sched_banks,) if self._sched_state else ()))
        exp, pop, ps = self.exp, self.exp.pop, self.pstate0
        avail = churn.availability(ps.arrival, ps.departure, t,
                                   jax.random.fold_in(key, SALT_AVAIL),
                                   self.avail_rate)
        cohort, member, rank = sample_cohort(
            jax.random.fold_in(key, SALT_SAMPLE), avail, pop.k_cohort)
        lat = stragglers.latencies(jax.random.fold_in(key, SALT_LATENCY),
                                   ps.speed[cohort])
        mask = (member
                & (rank.astype(jnp.float32) < self.k_active)
                & stragglers.deadline_mask(lat, self.straggler_deadline))
        xk, yk = self.data.cohort_batch(cohort)
        if lw.identity:
            # the pre-axis jaxpr, byte-for-byte — pins the goldens
            grads, _ = device_grads(
                params, self.unravel, xk, yk,
                jnp.zeros((pop.k_cohort, self.d), jnp.float32),
                local_steps=exp.local_steps, local_lr=exp.local_lr)
        else:
            duals = (gather_cohort(dual_banks, cohort) if lw.has_dual
                     else None)
            grads, _, new_duals = local_device_grads(
                lw, self._grad_fn, params, xk, yk,
                jnp.zeros((pop.k_cohort, self.d), jnp.float32), duals)
            if lw.has_dual:
                # masked-out cohort members did not run this round: their
                # dual must not evolve (the keep-rule round_masked applies
                # to the error banks); the scatter re-writes the gathered
                # value, claiming the slot with unchanged contents
                new_duals = jnp.where(mask[:, None], new_duals, duals)
                dual_banks = scatter_cohort(dual_banks, cohort, new_duals)
        draw = sched = None
        if self.scheduler is not None:
            # evaluate the cohort draw here so the scheduler ranks this
            # round's effective gains (same salted key population_round
            # would use — XLA sees one draw either way)
            draw = sch.cohort_channel_draw(jax.random.fold_in(key, 2), t,
                                           cohort, pop.m_total, mask=mask)
            draw = draw._replace(p_factor=draw.p_factor * ps.gains[cohort])
            sstate = (gather_cohort(sched_banks, cohort)[:, 0]
                      if self._sched_state else None)
            sched, new_sstate = scheduling.schedule(
                self.scheduler,
                jax.random.fold_in(key, scheduling.SALT_SCHED), t,
                draw.p_factor, sch.n_subbands, state=sstate, mask=mask)
            if self._sched_state:
                # masked cohort rows keep their banked average (the dual
                # keep-rule); live-but-unscheduled rows decay — that decay
                # IS proportional fairness
                new_sstate = jnp.where(mask, new_sstate, sstate)
                sched_banks = scatter_cohort(sched_banks, cohort,
                                             new_sstate[:, None])
        ghat, banks, met = population_round(
            sch, banks, cohort, mask.astype(jnp.float32), grads, t, key,
            self.ctx, pop.m_total, gains=ps.gains[cohort],
            sites=ps.site[cohort], n_sites=pop.n_sites,
            site_noise_scale=self.site_noise_scale,
            backhaul_sigma2=self.backhaul_sigma2,
            site_trim_frac=pop.site_trim_frac, draw=draw, sched=sched)
        extras = ((banks,) + ((dual_banks,) if lw.has_dual else ())
                  + ((sched_banks,) if self._sched_state else ()))
        if exp.guard is not None:
            params, opt_state, extras, gstate, loss, gmet = (
                guards.guarded_step(
                    exp.guard, gstate, self.opt, params, opt_state, ghat,
                    self.unravel, extras=extras, old_extras=old_extras,
                    loss_fn=lambda p: ce_loss(p, self.xt, self.yt)))
            out = {"acc": accuracy(params, self.xt, self.yt),
                   "loss": loss, "metrics": {**met, **gmet}}
            return (params, opt_state) + tuple(extras) + (gstate,), out
        params, opt_state = self.opt.apply(params, self.unravel(ghat),
                                           opt_state)
        out = {"acc": accuracy(params, self.xt, self.yt),
               "loss": ce_loss(params, self.xt, self.yt),
               "metrics": met}
        return (params, opt_state) + extras, out

    # ------------------------------------------------------- traced entry
    def run_segment(self, overrides: Dict[str, jnp.ndarray],
                    keys: jnp.ndarray, mask, carry, t0):
        """Scan rounds ``t0 .. t0 + len(keys)`` from an explicit carry.

        The checkpoint/resume building block (the population analogue of
        :meth:`CompiledExperiment.run_segment` — same contract, so
        :func:`repro.experiments.engine.run_checkpointed` drives both).
        ``mask`` is accepted for signature compatibility and must be None:
        populations draw their own participation masks per round.  Returns
        ``(carry, outs)``.
        """
        if mask is not None:
            raise ValueError("population runs draw their own masks")
        pop_ov = {k: v for k, v in overrides.items()
                  if k in POP_OVERRIDE_ATTRS}
        lw_ov = {k: v for k, v in overrides.items()
                 if k in LOCAL_OVERRIDE_ATTRS}
        sch_ov = {k: v for k, v in overrides.items()
                  if k not in POP_OVERRIDE_ATTRS
                  and k not in LOCAL_OVERRIDE_ATTRS}
        runner = self.with_overrides(**pop_ov) if pop_ov else self
        sch = (self.scheme.with_overrides(**sch_ov) if sch_ov
               else self.scheme)
        lw = (self.localwork.with_overrides(**lw_ov) if lw_ov
              else self.localwork)

        def body(carry, inp):
            t, key = inp
            return runner._round(sch, lw, carry, t, key)

        ts = t0 + jnp.arange(keys.shape[0])
        return jax.lax.scan(body, carry, (ts, keys))

    def run(self, overrides: Dict[str, jnp.ndarray], keys: jnp.ndarray):
        """One full run. Returns {"acc": (steps,), "loss": (steps,),
        "metrics": {...: (steps,)}, "params": pytree}."""
        carry, outs = self.run_segment(overrides, keys, None,
                                       self._carry0(), jnp.int32(0))
        outs["params"] = carry[0]
        return outs


def run_population(data: PopulationData, x_test, y_test, cfg: OTAConfig,
                   pop: PopulationConfig, steps: int, lr: float = 1e-3,
                   eval_every: int = 10, seed: int = 0,
                   optimizer: str = "adam", local_steps: int = 1,
                   local_lr: float = 0.1, use_kernel: bool = False,
                   guard: Optional[guards.GuardConfig] = None,
                   checkpoint_dir: Optional[str] = None,
                   checkpoint_every: int = 0, resume: bool = False,
                   stop_after_step=None) -> Optional[EngineRun]:
    """``run_compiled`` for populations: one jitted scan over sampled
    cohorts.  At K == M_total with the churn/straggler defaults the run is
    bitwise ``run_compiled`` on the same device tensors (the RNG layout
    and MAC order match; pinned by tests/test_population.py).

    ``guard`` and the ``checkpoint_*`` knobs mirror ``run_compiled``:
    in-scan round guardrails, and the segmented checkpoint/resume driver
    (returns ``None`` when ``stop_after_step`` interrupts the run)."""
    exp = PopulationExperiment(cfg=cfg, pop=pop, steps=steps, lr=lr,
                               eval_every=eval_every, optimizer=optimizer,
                               local_steps=local_steps, local_lr=local_lr,
                               seed=seed, use_kernel=use_kernel, guard=guard)
    cp = CompiledPopulation(data, x_test, y_test, exp)
    keys = round_keys(steps, seed)
    if checkpoint_dir is not None and checkpoint_every > 0:
        outs = run_checkpointed(cp, {}, keys, checkpoint_dir=checkpoint_dir,
                                checkpoint_every=checkpoint_every,
                                resume=resume,
                                stop_after_step=stop_after_step)
        if outs is None:
            return None
    else:
        outs = jax.jit(cp.run)({}, keys)
    outs = jax.tree.map(np.asarray, outs)
    return _subsample(outs, exp)
