"""Hierarchical aggregation: devices -> edge-site OTA sums -> backhaul.

Per *FL over Wireless D2D Networks* (arXiv:2101.12704), a massive
population does not share one MAC: devices associate with edge sites, each
site receives the OTA superposition of its own devices (with its own
receiver AWGN), and the sites' partial sums travel over a backhaul to the
PS, which combines them (optionally through one more noisy hop).  The
net observation is

    y = sum_j ( sum_{m in site j} x_m + z_j ) + z_bh,

so the effective MAC noise grows with the number of sites — the modeled
price of hierarchy — while per-site traffic shrinks.  ``site_noise_scale``
(per-site variance relative to the flat MAC's sigma^2) and
``backhaul_sigma2`` are traced scalars, hence vmappable sweep axes; at
``n_sites = 1`` the population engine bypasses this module entirely and
the flat ``mac_sum`` path is bitwise-preserved.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel
from repro.robust import aggregators


def site_assignment(m: int, n_sites: int) -> np.ndarray:
    """(M,) int32 device -> edge-site map (round-robin: balanced sites)."""
    return (np.arange(m) % n_sites).astype(np.int32)


def site_mac_sum(
    frames: jnp.ndarray,
    sites: jnp.ndarray,
    n_sites: int,
    key: jnp.ndarray,
    sigma2,
    site_noise_scale=1.0,
    backhaul_sigma2=0.0,
    site_trim_frac: float = 0.0,
) -> jnp.ndarray:
    """Two-stage MAC: per-site OTA partial sums, then the PS combine.

    frames: (K, s) cohort channel frames; sites: (K,) int32 site of each
    cohort device.  Site j's receiver adds AWGN of variance
    ``sigma2 * site_noise_scale`` (keyed ``fold_in(key, j)``); the combine
    adds ``backhaul_sigma2`` (0.0 adds exact zeros — bitwise-safe).

    ``site_trim_frac > 0`` (static) makes the backhaul combine *robust*:
    the PS takes the coordinate-wise trimmed mean of the sites' partial
    sums (scaled back to sum-equivalence) instead of the plain sum, so a
    site whose whole OTA observation is poisoned — a Byzantine-heavy cell,
    a jammed receiver — is discarded per coordinate.  The default 0.0
    keeps the literal ``jnp.sum`` path bitwise.
    """
    s = frames.shape[-1]
    partial = jax.ops.segment_sum(frames, sites, num_segments=n_sites)
    sig_site = jnp.asarray(sigma2, frames.dtype) * jnp.asarray(
        site_noise_scale, frames.dtype
    )
    z = jax.vmap(
        lambda j: channel.awgn(
            jax.random.fold_in(key, j), (s,), sig_site, frames.dtype
        )
    )(jnp.arange(n_sites))
    if site_trim_frac > 0.0:
        y = aggregators.robust_combine(
            partial + z, jnp.ones((n_sites,), bool), float(n_sites),
            aggregator="trimmed_mean", trim_frac=site_trim_frac,
        )
    else:
        y = jnp.sum(partial + z, axis=0)
    return y + channel.awgn(
        jax.random.fold_in(key, n_sites), y.shape, backhaul_sigma2, y.dtype
    )
