"""Deterministic (seed, t)-keyed cohort sampling.

Each round draws K of the currently-available devices uniformly without
replacement via the Gumbel-top-k trick: perturb every device with an iid
Gumbel score, mask the unavailable ones to -inf, and take the K best.  The
draw is a pure function of the round key (the engine passes
``fold_in(round_key, SALT_SAMPLE)``), so it evaluates identically inside
the compiled scan, under vmap, and in host-side reproductions.

The cohort is returned *sorted by device id*.  That makes the K == M
cohort exactly ``arange(M)``, so gathered data/keys/draws — and the MAC
summation order — match the dense drivers bitwise (the parity golden).
The pre-sort score rank of each cohort row is returned alongside: masking
``rank >= k_active`` shrinks the effective cohort to the *top* k_active
scores, which puts K on a vmappable sweep axis (the sampled analogue of
``m_active``).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_cohort(
    key: jnp.ndarray, avail: jnp.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Draw K participants from the available devices.

    avail: (M,) bool availability mask for this round.

    Returns ``(cohort, member, rank)``: device ids (K,) int32 sorted
    ascending; a bool mask marking rows that are genuinely available (when
    fewer than K devices are up, the tail rows are unavailable fillers the
    caller must mask out); and each row's score rank in [0, K).
    """
    m = avail.shape[0]
    if not 0 < k <= m:
        raise ValueError(f"need 0 < k <= M; got k={k}, M={m}")
    score = jax.random.gumbel(key, (m,)) + jnp.where(avail, 0.0, -jnp.inf)
    _, ids = jax.lax.top_k(score, k)
    order = jnp.argsort(ids)
    cohort = ids[order].astype(jnp.int32)
    return cohort, avail[cohort], order.astype(jnp.int32)
