"""Banked, shard-addressed per-device state for M-large populations.

The legacy drivers carry per-device state as dense ``(M, d)`` arrays, which
caps M at a few dozen.  This module stores the population's persistent
state — error-feedback accumulators, large-scale channel gains, compute
speeds, arrival/departure traces, edge-site ids — in a
:class:`PopulationState` pytree whose d-sized part is *banked*: a
``(n_banks, bank_size, d)`` array addressed by ``slot = device_id % S``
(``S = n_banks * bank_size`` slots), with gather/scatter cohort views so a
round only ever touches ``(K, d)`` temporaries.

Capacity is the memory knob: ``capacity == m_total`` (the default) gives
every device its own slot — error feedback is exact, and a K == M cohort
reproduces the dense drivers bitwise (the parity golden).  ``capacity <
m_total`` turns the banks into a direct-mapped cache: devices that share a
slot evict each other, and an evicted device restarts from the cold state
``Delta = 0`` (exactly the accumulator a fresh device would carry — under
sampled cohorts with rare revisits the lost residual is a second-order
term, and peak memory drops to ``O(m_total * d / r)`` for an ``r``-fold
capacity reduction).  An ``owner`` array detects cold slots on gather.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PopulationConfig:
    """Static description of one device population.

    ``m_total`` devices keep persistent state; each round samples a
    ``k_cohort``-device cohort.  ``capacity`` (0 = ``m_total``) bounds the
    banked error-feedback slots; ``bank_size`` sets the bank granularity.
    The churn/straggler/hierarchy fields parameterise the availability,
    latency, and edge-site models (``population/{churn,stragglers,
    hierarchy}.py``); ``avail_rate`` / ``straggler_deadline`` and the two
    site-noise scalars are *traced* per-round data, so the sweep engine can
    vmap grids over them (docs/DESIGN.md §9).
    """

    m_total: int
    k_cohort: int
    bank_size: int = 256
    capacity: int = 0  # 0 => one slot per device (exact error feedback)
    # churn: arrival/departure trace + per-round Bernoulli availability
    arrival_spread: float = 0.0  # fraction of the run over which devices arrive
    mean_lifetime: float = 0.0  # mean rounds before departure; 0 => immortal
    avail_rate: float = 1.0  # per-round availability probability (traced)
    # stragglers: lognormal compute speeds, exponential latency, deadline
    speed_sigma: float = 0.0  # lognormal sigma of per-device slowdown
    straggler_deadline: float = float("inf")  # round deadline (traced)
    # large-scale channel gains (received-power factors, static per device)
    shadowing_sigma_db: float = 0.0
    # hierarchy: devices -> edge-site partial OTA sums -> backhaul combine
    n_sites: int = 1
    site_noise_scale: float = 1.0  # per-site AWGN variance scale (traced)
    backhaul_sigma2: float = 0.0  # inter-site combine noise (traced)
    # robust backhaul: trimmed-mean combine over site partials (static;
    # 0.0 keeps the plain-sum path bitwise — repro.population.hierarchy)
    site_trim_frac: float = 0.0
    seed: int = 0

    def __post_init__(self) -> None:
        if not 0 < self.k_cohort <= self.m_total:
            raise ValueError(
                f"k_cohort must be in (0, m_total]; got K={self.k_cohort}, "
                f"M={self.m_total}"
            )
        if self.capacity < 0 or self.bank_size <= 0 or self.n_sites <= 0:
            raise ValueError("capacity/bank_size/n_sites must be positive")

    @property
    def state_capacity(self) -> int:
        return self.capacity or self.m_total

    @property
    def n_banks(self) -> int:
        return -(-self.state_capacity // self.bank_size)


class BankedState(NamedTuple):
    """Direct-mapped banked store of per-device ``(d,)`` vectors."""

    deltas: jnp.ndarray  # (n_banks, bank_size, d) error accumulators
    owner: jnp.ndarray  # (n_banks, bank_size) int32 device id, -1 = empty


class PopulationState(NamedTuple):
    """The whole population's persistent state, as a pytree.

    Only ``banks`` evolves round to round (it rides the scan carry); the
    remaining ``(M,)`` scalar fields are drawn once per run.
    """

    banks: BankedState
    gains: jnp.ndarray  # (M,) large-scale received-power factors
    speed: jnp.ndarray  # (M,) compute slowdown factors (>= 0)
    arrival: jnp.ndarray  # (M,) int32 first round the device exists
    departure: jnp.ndarray  # (M,) int32 first round after it leaves
    site: jnp.ndarray  # (M,) int32 edge-site assignment


#: departure round of an immortal device (any int32 far above any horizon)
NEVER = 1 << 30


def init_banks(
    capacity: int, bank_size: int, d: int, dtype=jnp.float32
) -> BankedState:
    """All-cold banks: ``ceil(capacity / bank_size)`` banks, owner = -1."""
    n_banks = -(-capacity // bank_size)
    return BankedState(
        deltas=jnp.zeros((n_banks, bank_size, d), jnp.dtype(dtype)),
        owner=jnp.full((n_banks, bank_size), -1, jnp.int32),
    )


def _address(banks: BankedState, cohort: jnp.ndarray):
    """(bank, slot) coordinates of each cohort device (direct-mapped)."""
    bank_size = banks.owner.shape[1]
    n_slots = banks.owner.size
    slot = cohort.astype(jnp.int32) % n_slots
    return slot // bank_size, slot % bank_size


def gather_cohort(banks: BankedState, cohort: jnp.ndarray) -> jnp.ndarray:
    """(K, d) cohort view of the banked state; cold slots read as zeros.

    A slot is *live* for a device iff the owner tag matches its id — a
    fresh or evicted device reads the cold state ``Delta = 0`` (the
    accumulator every device starts from, so capacity == m_total is exact
    and smaller capacities degrade gracefully)."""
    b, s = _address(banks, cohort)
    live = banks.owner[b, s] == cohort.astype(jnp.int32)
    return jnp.where(live[:, None], banks.deltas[b, s], 0.0)


def scatter_cohort(
    banks: BankedState, cohort: jnp.ndarray, new_deltas: jnp.ndarray
) -> BankedState:
    """Write the cohort's updated accumulators back (claiming ownership).

    With capacity < m_total two cohort devices can collide on one slot; the
    lowest device id wins deterministically (later writers drop), so the
    update is well-defined regardless of XLA's scatter order."""
    b, s = _address(banks, cohort)
    k = cohort.shape[0]
    i = jnp.arange(k)
    dup = (b[:, None] == b[None, :]) & (s[:, None] == s[None, :]) & (
        i[:, None] > i[None, :]
    )
    keep = ~jnp.any(dup, axis=1)
    # dropped rows are routed out of range and discarded by mode="drop"
    b = jnp.where(keep, b, banks.owner.shape[0])
    return BankedState(
        deltas=banks.deltas.at[b, s].set(
            new_deltas.astype(banks.deltas.dtype), mode="drop"
        ),
        owner=banks.owner.at[b, s].set(cohort.astype(jnp.int32), mode="drop"),
    )


def init_population(
    pop: PopulationConfig,
    d: int,
    steps: int,
    dtype=jnp.float32,
    key: Optional[jnp.ndarray] = None,
) -> PopulationState:
    """Draw the run-level per-device arrays and allocate cold banks.

    ``steps`` anchors the arrival trace: a fraction ``arrival_spread`` of
    the run is the window over which devices first appear."""
    from repro.population import churn, hierarchy, stragglers

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    m = pop.m_total
    k_gain, k_speed, k_churn = jax.random.split(key, 3)
    if pop.shadowing_sigma_db > 0:
        db = pop.shadowing_sigma_db * jax.random.normal(k_gain, (m,))
        gains = jnp.power(10.0, db / 10.0)
    else:
        gains = jnp.ones((m,))
    arrival, departure = churn.init_arrival_departure(
        k_churn, m, steps, pop.arrival_spread, pop.mean_lifetime
    )
    return PopulationState(
        banks=init_banks(pop.state_capacity, pop.bank_size, d, dtype),
        gains=gains,
        speed=stragglers.init_speed(k_speed, m, pop.speed_sigma),
        arrival=arrival,
        departure=departure,
        site=jnp.asarray(hierarchy.site_assignment(m, pop.n_sites)),
    )
