"""Massive-cohort population engine: sampled rounds over 10^4-10^6 devices.

Public surface of the subsystem (docs/DESIGN.md §9): banked per-device
state with gather/scatter cohort views (:mod:`.state`), deterministic
Gumbel-top-k cohort sampling (:mod:`.sampler`), churn and straggler models
(:mod:`.churn`, :mod:`.stragglers`), hierarchical edge-site aggregation
(:mod:`.hierarchy`), and the compiled sampled-cohort round engine
(:mod:`.engine`).  Sweep grids over population axes run through
:func:`repro.experiments.run_population_sweep`.
"""

from repro.population.engine import (
    POP_OVERRIDE_ATTRS, CompiledPopulation, PopulationData,
    PopulationExperiment, population_round, run_population,
)
from repro.population.hierarchy import site_assignment, site_mac_sum
from repro.population.sampler import sample_cohort
from repro.population.state import (
    BankedState, PopulationConfig, PopulationState, gather_cohort,
    init_banks, init_population, scatter_cohort,
)

__all__ = [
    "BankedState",
    "CompiledPopulation",
    "POP_OVERRIDE_ATTRS",
    "PopulationConfig",
    "PopulationData",
    "PopulationExperiment",
    "PopulationState",
    "gather_cohort",
    "init_banks",
    "init_population",
    "population_round",
    "run_population",
    "sample_cohort",
    "scatter_cohort",
    "site_assignment",
    "site_mac_sum",
]
