"""Churn: arrival/departure traces + per-round availability.

Two time scales compose into one bool mask per round:

* a run-level **arrival–departure trace** — device m exists during
  ``[arrival_m, departure_m)``, with arrivals spread over the first
  ``arrival_spread`` fraction of the run and exponential lifetimes of mean
  ``mean_lifetime`` rounds (0 = immortal);
* a per-round **Bernoulli availability** draw at rate ``avail_rate`` —
  the device is up but may be off-charger/off-wifi this round.

``avail_rate`` enters as a traced compare (``uniform < rate``), so it is a
vmappable sweep axis; the trace arrays are drawn once per run.  At the
defaults (no spread, immortal, rate 1.0) every device is available every
round — ``uniform(key) < 1.0`` is always true, preserving the K == M
bitwise parity path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.population.state import NEVER


def init_arrival_departure(
    key: jnp.ndarray,
    m: int,
    steps: int,
    arrival_spread: float = 0.0,
    mean_lifetime: float = 0.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(arrival, departure) int32 round indices per device."""
    k_arr, k_life = jax.random.split(key)
    if arrival_spread > 0:
        window = max(1.0, arrival_spread * steps)
        arrival = jnp.floor(
            jax.random.uniform(k_arr, (m,)) * window
        ).astype(jnp.int32)
    else:
        arrival = jnp.zeros((m,), jnp.int32)
    if mean_lifetime > 0:
        life = jnp.ceil(
            jax.random.exponential(k_life, (m,)) * mean_lifetime
        ).astype(jnp.int32)
        departure = arrival + jnp.maximum(life, 1)
    else:
        departure = jnp.full((m,), NEVER, jnp.int32)
    return arrival, departure


def availability(
    arrival: jnp.ndarray,
    departure: jnp.ndarray,
    t,
    key: jnp.ndarray,
    avail_rate,
) -> jnp.ndarray:
    """(M,) bool: device exists at round t AND is up this round."""
    present = (arrival <= t) & (t < departure)
    up = jax.random.uniform(key, arrival.shape) < jnp.asarray(
        avail_rate, jnp.float32
    )
    return present & up
