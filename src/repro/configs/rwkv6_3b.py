"""RWKV6 (Finch) 3B — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, head_dim=64,
    rwkv=RWKVConfig(head_dim=64, chunk=256, decay_lora=64),
    citation="arXiv:2404.05892",
)
