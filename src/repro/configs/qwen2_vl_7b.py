"""Qwen2-VL-7B backbone — M-RoPE decoder; vision tower STUBBED (patch
embeddings in). [arXiv:2409.12191]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
    citation="arXiv:2409.12191",
)
