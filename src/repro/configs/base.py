"""Config system: dataclass configs for models, OTA aggregation, training, shapes.

Every assigned architecture gets a module in this package exposing ``CONFIG``
(an :class:`ArchConfig` with the exact published hyper-parameters) and the
registry in :func:`get_config` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

# Block kinds understood by models/transformer.py
ATTN = "attn"            # full (GQA) self-attention + MLP
SWA = "swa"              # sliding-window self-attention + MLP
MAMBA2 = "mamba2"        # Mamba2 (SSD) mixer block
RWKV6 = "rwkv6"          # RWKV-6 (Finch) time-mix + channel-mix block
MOE = "moe"              # GQA self-attention + MoE MLP


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int          # hidden dim of each expert's SwiGLU
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2        # d_inner = expand * d_model
    head_dim: int = 64     # SSD head dim
    conv_width: int = 4
    chunk: int = 256       # SSD chunk length


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 256       # chunked WKV recurrence length
    decay_lora: int = 64   # low-rank dim of the data-dependent decay
    ffn_mult: Optional[int] = None  # d_ff explicit on ArchConfig


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed: inputs are frame embeds)."""
    n_layers: int = 6
    n_frames: int = 1500   # encoder sequence length after the (stubbed) conv
    d_model: int = 512
    n_heads: int = 8
    d_ff: int = 2048


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None     # if set, SWA blocks
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # block pattern; if None, inferred from family
    block_pattern: Optional[Tuple[str, ...]] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None  # enc-dec (whisper)
    # hybrid (zamba2): one shared attention block applied every `shared_attn_every`
    # mamba layers, with shared (reused) weights.
    shared_attn_every: int = 0
    # vlm (qwen2-vl): M-RoPE section split of head_dim/2 into (t, h, w)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    n_vision_tokens: int = 0         # stub patch-embedding prefix length
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def blocks(self) -> Tuple[str, ...]:
        """The per-layer block kinds (length n_layers)."""
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        if self.family == "moe":
            return (MOE,) * self.n_layers
        if self.family == "ssm":
            return (RWKV6,) * self.n_layers if self.rwkv else (MAMBA2,) * self.n_layers
        if self.family == "hybrid":
            return (MAMBA2,) * self.n_layers
        # dense / audio decoder / vlm
        return (ATTN,) * self.n_layers

    def reduced(self) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests (2 layers, d<=512)."""
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 256),
            vocab=min(self.vocab, 512),
            head_dim=32,
            block_pattern=None,
            n_vision_tokens=min(self.n_vision_tokens, 8),
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_expert=64)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=32, chunk=32)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_dim=32, chunk=32, decay_lora=16)
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(n_layers=2, n_frames=16, d_model=d_model,
                                          n_heads=n_heads, d_ff=128)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 6, 6)   # sums to head_dim/2 = 16
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# OTA aggregation config (the paper's technique)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OTAConfig:
    """Configuration of the gradient aggregation channel (paper §II-IV).

    ``scheme`` names any entry of the scheme registry
    (:mod:`repro.core.schemes`): the paper's ``ideal | a_dsgd | d_dsgd |
    signsgd | qsgd`` plus registered extensions such as ``a_dsgd_fading``
    (truncated-inversion Rayleigh MAC) and user schemes added with
    ``@register_scheme``.
    """
    scheme: str = "a_dsgd"     # any registered scheme name
    # channel
    s_frac: float = 0.5        # s = s_frac * d channel uses per iteration
    sigma2: float = 1.0        # AWGN variance (sigma^2)
    p_avg: float = 500.0       # average power budget P-bar
    power_schedule: str = "constant"   # constant | lh_stair | lh_steps | hl_steps
    total_steps: int = 300     # T, for the average-power constraint
    # A-DSGD
    k_frac: float = 0.5        # k = k_frac * s sparsity level
    amp_iters: int = 20
    mean_removal_steps: int = 20   # use the §IV-A variant for the first N steps
    # D-DSGD / digital baselines
    quant_bits: int = 2        # QSGD l_Q
    # projection realisation
    projection: str = "dense"  # dense (paper) | blocked (TPU framework path)
    block_size: int = 4096     # c — chunk of the flattened gradient (blocked path)
    rademacher: bool = False   # blocked path: ±1/sqrt(s_c) entries (kernel-friendly)
    use_kernel: bool = False   # route the blocked projection through Pallas
    # distribution
    num_groups: int = 0        # 0 => one OTA device per ('pod','data') coordinate
    state_dtype: str = "float32"   # error-accumulator dtype
    seed: int = 0
    # beyond-paper performance knobs (§Perf; defaults = paper-faithful)
    layout: str = "flat"       # flat | sliced (slice-local leafwise flatten)
    frame_dtype: str = "float32"   # bf16 halves the MAC psum payload
    shard_decode: bool = False     # split the redundant PS AMP across devices
    # beyond-paper channel model (follow-up [34]): block-flat Rayleigh fading
    # with truncated channel inversion.  ``fading="rayleigh"`` is the legacy
    # spelling — it promotes scheme "a_dsgd" to "a_dsgd_fading" in get_scheme.
    fading: str = "none"           # none | rayleigh
    fading_threshold: float = 0.3
    # channel-model axis (repro.core.fading): how gains evolve over rounds
    # and what the transmitters know about them.  fading_process selects the
    # traced program structure (static axis); rho / csi_err_var enter the
    # round as data, so they are vmappable sweep axes (docs/DESIGN.md §8).
    fading_process: str = "iid"    # static | iid | gauss_markov
    fading_rho: float = 0.9        # gauss_markov AR(1) correlation
    fading_window: int = 64        # gauss_markov moving-average window W
    csi_err_var: float = 0.0       # CSI estimate error variance (a_dsgd_csi_err)
    ps_antennas: int = 32          # K PS receive antennas (a_dsgd_blind)
    # robustness axis (repro.robust): fault injection + robust aggregation.
    # Defaults are bitwise-neutral: with ``robust=False`` and the zero rates
    # below, no new op enters the traced program (static gating), so every
    # pre-existing golden stays byte-identical.  ``robust=True`` (set
    # explicitly, or auto-promoted by the sweep engine when a robust axis is
    # swept) compiles the fault-injection path; the *rates* then enter the
    # round as traced scalars, so whole fault grids vmap on one program
    # (``ROBUST_VMAP_AXES`` in repro.experiments.sweep).
    robust: bool = False           # static master switch for fault injection
    byzantine_frac: float = 0.0    # persistent Byzantine fraction (traced)
    byz_attack: str = "sign_flip"  # static attack shape: sign_flip | scale
    byz_scale: float = 10.0        # attack magnitude (traced)
    fault_rate: float = 0.0        # per-round transient fault prob (traced)
    fault_kind: str = "nan"        # static: nan | inf | stale | dropout
    erasure_prob: float = 0.0      # digital packet-erasure prob (traced)
    # robust aggregation (independent of fault injection; static gates)
    aggregator: str = "mean"       # mean | trimmed_mean | median | norm_cap
    trim_frac: float = 0.1         # per-side trim fraction (traced)
    norm_cap: float = 1.0          # per-frame L2 cap, norm_cap agg (traced)
    clip_power: bool = False       # static: analog transmit-side power cap
    power_cap: float = 1.5         # cap as a multiple of P_t (traced)
    # geometry axis (repro.core.geometry): placement-derived large-scale
    # gains composed onto the small-scale fading draw.  ``geometry`` is the
    # static gate (``"none"`` keeps every pre-geometry golden byte-identical
    # — no geometry op enters the trace); cell_radius / path_loss_exp enter
    # the round as one traced scalar each (SCALAR_VMAP_AXES), the remaining
    # fields are structural GeometrySpec bits (docs/DESIGN.md §12).
    geometry: str = "none"         # none | disk (static placement model)
    cell_radius: float = 1000.0    # cell radius R in meters (traced)
    path_loss_exp: float = 3.0     # path-loss exponent gamma (traced)
    carrier_freq: float = 915e6    # f_c in Hz (static; link-budget diagnostics)
    bs_gain_db: float = 5.0        # BS antenna gain in dBi (static)
    user_gain_db: float = 0.0      # device antenna gain in dBi (static)
    bs_height: float = 10.0        # BS mast height in meters (static)
    geo_ref_dist: float = 100.0    # d0: gain = antenna gains alone (static)
    # subband scheduling axis (repro.core.scheduling): which devices
    # transmit each round.  ``scheduler`` selects the registered policy
    # (static program structure; "none" compiles no scheduling op);
    # ``n_subbands`` enters as a traced rank cutoff (SCALAR_VMAP_AXES);
    # ``pf_horizon`` shapes the prop_fair averaging and stays static.
    scheduler: str = "none"        # none | round_robin | gain_ranked | prop_fair
    n_subbands: int = 4            # S transmit slots per round (traced)
    pf_horizon: float = 10.0       # prop_fair average-rate horizon (static)
    # local-compute axis (repro.local): what devices do between uplinks.
    # ``local`` selects the registered algorithm (static program structure);
    # ``local_epochs`` / ``prox_mu`` / ``dyn_alpha`` enter the round as one
    # traced scalar each (LOCAL_VMAP_AXES in repro.experiments.sweep — the
    # epoch count rides a masked scan bounded by the static grid maximum).
    # Defaults are the paper's single-SGD-step device and keep every
    # committed golden byte-identical (docs/DESIGN.md §11).
    local: str = "sgd"             # sgd | fedavg | fedprox | feddyn
    local_epochs: int = 1          # E local passes per round (traced count)
    prox_mu: float = 0.0           # FedProx proximal strength mu (traced)
    dyn_alpha: float = 0.0         # FedDyn regulariser alpha (traced)

    def s_for(self, d: int) -> int:
        return max(2, int(self.s_frac * d))

    def k_for(self, d: int) -> int:
        return max(1, int(self.k_frac * self.s_for(d)))


# ---------------------------------------------------------------------------
# Train / shape configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adam"        # sgd | momentum | adam
    lr: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "zamba2_7b",
    "mistral_large_123b",
    "granite_moe_1b_a400m",
    "smollm_360m",
    "rwkv6_3b",
    "granite_moe_3b_a800m",
    "qwen3_8b",
    "yi_34b",
    "whisper_base",
    "qwen2_vl_7b",
)


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS and arch != "mnist_mlp":
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + ('mnist_mlp',)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def ota_overrides(arch: str) -> OTAConfig:
    """Per-arch OTA defaults (framework path: blocked projection, modest rho)."""
    cfg = get_config(arch)
    n_params_b = approx_param_count(cfg) / 1e9
    state_dtype = "bfloat16" if n_params_b >= 30 else "float32"
    num_groups = 4 if n_params_b >= 30 else 0
    return OTAConfig(projection="blocked", s_frac=0.25, k_frac=0.5,
                     rademacher=True, state_dtype=state_dtype,
                     num_groups=num_groups, block_size=4096)


def approx_param_count(cfg: ArchConfig) -> int:
    """Closed-form parameter count used for rooflines (6ND model FLOPs)."""
    d, h = cfg.d_model, cfg.resolved_head_dim
    n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
    total = cfg.vocab * d                       # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * d                  # lm head
    attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
    swiglu = 3 * d * cfg.d_ff
    moe = 0
    if cfg.moe is not None:
        moe = cfg.moe.num_experts * 3 * d * cfg.moe.d_expert + d * cfg.moe.num_experts
    ssm = 0
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * d
        ssm = d * (2 * d_in + 2 * cfg.ssm.d_state) + d_in * d + 2 * d_in
    rwkv = 0
    if cfg.rwkv is not None:
        rwkv = 4 * d * d + d * d  # r,k,v,g,o projections approx
        rwkv += 2 * d * cfg.rwkv.decay_lora
        rwkv += 2 * d * cfg.d_ff // 1 if cfg.d_ff else 0
    for kind in cfg.blocks():
        if kind in (ATTN, SWA):
            total += attn + swiglu
        elif kind == MOE:
            total += attn + moe
        elif kind == MAMBA2:
            total += ssm
        elif kind == RWKV6:
            total += rwkv
    if cfg.shared_attn_every:
        total += attn + swiglu                   # one shared block
    if cfg.encoder is not None:
        e = cfg.encoder
        total += e.n_layers * (4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff)
        total += cfg.n_layers * (4 * cfg.d_model * cfg.d_model)  # cross-attn
    return int(total)


def active_param_count(cfg: ArchConfig) -> int:
    """Active (per-token) params — MoE counts only top_k experts."""
    if cfg.moe is None:
        return approx_param_count(cfg)
    full = approx_param_count(cfg)
    m = cfg.moe
    dead = (m.num_experts - m.top_k) * 3 * cfg.d_model * m.d_expert
    n_moe = sum(1 for k in cfg.blocks() if k == MOE)
    return int(full - n_moe * dead)
