"""Granite-3.0-1B-A400M — MoE, 32 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
