"""The paper's own model: single-layer network on 28x28 inputs, 10 classes,
d = 784*10 + 10 = 7850 parameters (paper §VI)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mnist-mlp", family="mlp",
    n_layers=1, d_model=784, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=10,
    citation="Amiri & Gunduz 2020, §VI",
)
