"""Zamba2-7B — hybrid Mamba2 backbone with a shared GQA attention block
applied every 6 Mamba2 layers (weights reused). [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4, chunk=256),
    shared_attn_every=6,
    citation="arXiv:2411.15242",
)
