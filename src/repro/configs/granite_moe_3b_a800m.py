"""Granite-3.0-3B-A800M — MoE, 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
