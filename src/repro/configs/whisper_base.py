"""Whisper-base — enc-dec; conv/mel frontend STUBBED (frame embeddings in).
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    encoder=EncoderConfig(n_layers=6, n_frames=1500, d_model=512,
                          n_heads=8, d_ff=2048),
    citation="arXiv:2212.04356",
)
