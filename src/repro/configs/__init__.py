from repro.configs.base import (  # noqa: F401
    ARCH_IDS, INPUT_SHAPES, ArchConfig, EncoderConfig, MoEConfig, OTAConfig,
    RWKVConfig, SSMConfig, ShapeConfig, TrainConfig, active_param_count,
    approx_param_count, get_config, ota_overrides,
)
