"""Paper-scale federated trainer (§VI): M devices, single-layer classifier,
d = 7850, aggregation over the simulated Gaussian MAC.

:func:`run_federated` is the *looped reference implementation*: one jitted
round per Python iteration, host evals in between.  The figure benchmarks
run on the compiled engine instead (:mod:`repro.experiments`: the whole
run as one jitted scan, grids vmapped on top), which is pinned bitwise
against this loop by tests/test_experiments.py — both share the
device-side compute in :func:`device_grads`.  The model/optimizer follow
the paper: single-layer softmax network trained with ADAM at the PS on
the reconstructed gradient.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core.schemes import get_scheme, round_simulated
from repro.local.work import get_local, local_device_grads
from repro.optim.optim import Optimizer


def init_linear(dim: int, n_classes: int, key) -> Dict[str, jnp.ndarray]:
    return {"w": jnp.zeros((dim, n_classes), jnp.float32),
            "b": jnp.zeros((n_classes,), jnp.float32)}


def predict(params, x):
    return x @ params["w"] + params["b"]


def ce_loss(params, x, y):
    logits = predict(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(predict(params, x), -1) == y)


@dataclass
class FederatedRun:
    accs: List[float] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)
    metrics: List[Dict[str, float]] = field(default_factory=list)


def flat_grad(params, xm, ym):
    """One device's flattened gradient on its local batch."""
    g = jax.grad(ce_loss)(params, xm, ym)
    return jax.flatten_util.ravel_pytree(g)[0]


def flat_grad_fn(unravel):
    """``(w_flat, xm, ym) -> (d,)`` flat-gradient closure — the per-epoch
    hook :func:`repro.local.work.local_device_grads` drives (injected so
    ``repro.local`` stays model-agnostic)."""

    def gf(wflat, xm, ym):
        g = jax.grad(ce_loss)(unravel(wflat), xm, ym)
        return jax.flatten_util.ravel_pytree(g)[0]

    return gf


def flat_local_delta(params, unravel, xm, ym, local_steps: int,
                     local_lr: float):
    """J local SGD steps; transmit (theta - theta_m^J)/(J * local_lr)."""
    wflat = jax.flatten_util.ravel_pytree(params)[0]

    def body(w, _):
        g = jax.grad(ce_loss)(unravel(w), xm, ym)
        return w - local_lr * jax.flatten_util.ravel_pytree(g)[0], None

    w_j, _ = jax.lax.scan(body, wflat, None, length=local_steps)
    return (wflat - w_j) / (local_lr * local_steps)


def device_grads(params, unravel, xd, yd, momenta, *, local_steps: int = 1,
                 local_lr: float = 0.1, momentum_correction: float = 0.0):
    """(M, d) per-device gradients + updated momenta — the device-side
    compute shared bitwise between :func:`run_federated` and the compiled
    engine (:mod:`repro.experiments.engine`)."""
    if local_steps > 1:
        grads = jax.vmap(lambda xm, ym: flat_local_delta(
            params, unravel, xm, ym, local_steps, local_lr))(xd, yd)
    else:
        grads = jax.vmap(lambda xm, ym: flat_grad(params, xm, ym))(xd, yd)
    if momentum_correction > 0:
        momenta = momentum_correction * momenta + grads
        grads = momenta
    return grads, momenta


def run_federated(x_dev: np.ndarray, y_dev: np.ndarray,
                  x_test: np.ndarray, y_test: np.ndarray,
                  ota: OTAConfig, steps: int, lr: float = 1e-3,
                  eval_every: int = 10, seed: int = 0,
                  optimizer: str = "adam",
                  local_steps: int = 1, local_lr: float = 0.1,
                  momentum_correction: float = 0.0) -> FederatedRun:
    """Train the paper's model with the given aggregation scheme.

    Beyond-paper extensions the paper explicitly invites (§I-B):
      local_steps > 1        — FedAvg-style local SGD: each device runs J
                               local steps and transmits its MODEL DELTA
                               (the innovation) through the same channel.
      momentum_correction>0  — DGC-style [3]: devices compress the momentum
                               u = beta*u + g instead of the raw gradient.
      ota.local != "sgd" or ota.local_epochs > 1 — the registered
                               local-compute axis (repro.local): FedAvg-E /
                               FedProx / FedDyn inner loops, sharing the
                               delta convention above.
    """
    m, b, dim = x_dev.shape
    n_classes = int(y_dev.max()) + 1
    key = jax.random.PRNGKey(seed)
    params = init_linear(dim, n_classes, key)
    flat0, unravel = jax.flatten_util.ravel_pytree(params)
    d = flat0.shape[0]
    scheme = get_scheme(ota, d, m)
    if ota.scheduler != "none":
        raise ValueError(
            "subband scheduling needs carried scheduler state; the looped "
            "reference driver has none — use run_compiled/run_population "
            f"for scheduler={ota.scheduler!r}")
    lw = get_local(ota, local_lr)
    if not lw.identity and local_steps > 1:
        raise ValueError(
            "local_steps > 1 (the legacy FedAvg path) conflicts with the "
            f"configured local algorithm {ota.local!r} at "
            f"local_epochs={ota.local_epochs}; use ota.local_epochs")
    gf = flat_grad_fn(unravel)
    opt = Optimizer(name=optimizer, lr=lr)
    opt_state = opt.init(params)
    deltas = jnp.zeros((m, d), jnp.float32)
    momenta = jnp.zeros((m, d), jnp.float32)
    duals = lw.init_dual(m, d)
    xd, yd = jnp.asarray(x_dev), jnp.asarray(y_dev)
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)

    @jax.jit
    def step_fn(params, opt_state, deltas, momenta, duals, t, kk):
        if lw.identity:
            grads, momenta_n = device_grads(
                params, unravel, xd, yd, momenta, local_steps=local_steps,
                local_lr=local_lr, momentum_correction=momentum_correction)
        else:
            grads, momenta_n, duals = local_device_grads(
                lw, gf, params, xd, yd, momenta, duals,
                momentum_correction=momentum_correction)
        ghat, deltas, met = round_simulated(scheme, grads, deltas, t, kk)
        params, opt_state = opt.apply(params, unravel(ghat), opt_state)
        return params, opt_state, deltas, momenta_n, duals, met

    run = FederatedRun()
    for t in range(steps):
        params, opt_state, deltas, momenta, duals, met = step_fn(
            params, opt_state, deltas, momenta, duals, t,
            jax.random.PRNGKey(1000 + t))
        if t % eval_every == 0 or t == steps - 1:
            acc = float(accuracy(params, xt, yt))
            ls = float(ce_loss(params, xt, yt))
            run.accs.append(acc)
            run.losses.append(ls)
            run.metrics.append({k: float(v) for k, v in met.items()})
    return run
