"""Checkpointing: pytree <-> npz with path-encoded keys (no orbax offline).

Dict-of-dict pytrees (our params/opt/delta states) round-trip exactly;
keys are '/'-joined paths.  Arrays are gathered to host (np.asarray) — at
real scale this would be a per-shard async write; the format keeps that
extension trivial (one npz per host).

Two round-trip edge cases are handled explicitly:

* **Extended dtypes** (bfloat16 and friends from ml_dtypes) are not native
  npz dtypes — ``np.savez`` degrades them to opaque void records that
  ``jnp.asarray`` rejects on load.  Leaves whose dtype has kind ``'V'``
  are stored as a same-width unsigned-int bit-pattern view with the dtype
  name appended to the key (``path::bfloat16``) and viewed back on load.
  Complex dtypes are native to npz and pass through untouched.
* **Empty containers** (``{}``, ``()``) produce no leaves, so a naive
  flatten drops them and the restored tree has a different structure.
  They are recorded as zero-length sentinel leaves and rebuilt exactly.

NamedTuples still degrade to plain tuples (npz keys carry no class); when
a restored subtree must feed a jit carry, rebuild it against a reference:
``jax.tree.unflatten(jax.tree.structure(ref), jax.tree.leaves(loaded))``.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

_EMPTY_DICT = "__empty_dict__"
_EMPTY_TUPLE = "__empty_tuple__"
_UINT_FOR_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode_leaf(arr: np.ndarray) -> tuple:
    """(key_suffix, storable array): bit-pattern view for non-native dtypes."""
    if arr.dtype.kind == "V":  # ml_dtypes extension dtype (bfloat16, fp8, ...)
        raw = arr.view(_UINT_FOR_WIDTH[arr.dtype.itemsize])
        return f"::{arr.dtype.name}", raw
    return "", arr


def _decode_leaf(key: str, val: np.ndarray) -> tuple:
    """Invert :func:`_encode_leaf`: (path, array with original dtype)."""
    if "::" in key:
        path, name = key.rsplit("::", 1)
        return path, val.view(np.dtype(name))
    return key, val


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[f"{prefix}{_EMPTY_DICT}"] = np.zeros((0,), np.int8)
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out[f"{prefix}{_EMPTY_TUPLE}"] = np.zeros((0,), np.int8)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        suffix, arr = _encode_leaf(np.asarray(tree))
        out[prefix[:-1] + suffix] = arr
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        key, val = _decode_leaf(key, val)
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        if _EMPTY_DICT in node:
            return {}
        if _EMPTY_TUPLE in node:
            return ()
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, state: Any, step: int = 0) -> None:
    flat = _flatten({"state": state, "meta": {"step": np.asarray(int(step))}})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, shardings=None):
    """Returns ``(state, step)``; ``step`` is always the saved python int
    (0 for files written before the ``meta`` block existed).

    The step is read from the raw npz entry, not the rebuilt pytree —
    ``_unflatten`` routes leaves through ``jnp.asarray``, which truncates
    int64 to int32 under the default x64-disabled config.

    ``shardings`` (optional) is a pytree of shardings matching the saved
    state: leaves are ``device_put`` straight onto their placement so a
    resumed serve/train loop never round-trips a replicated copy through
    the default device (the fedllm mid-sweep resume path).  Its structure
    must match the *restored* tree (post npz round-trip, so tuples where
    NamedTuples were).
    """
    with np.load(path) as f:
        flat = {k: f[k] for k in f.files}
    step = int(flat.pop("meta/step")) if "meta/step" in flat else 0
    tree = _unflatten(flat)
    if isinstance(tree, dict):
        tree.pop("meta", None)
        tree = tree.get("state", tree)
    if shardings is not None:
        import jax
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    return tree, step
