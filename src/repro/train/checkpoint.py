"""Checkpointing: pytree <-> npz with path-encoded keys (no orbax offline).

Dict-of-dict pytrees (our params/opt/delta states) round-trip exactly;
keys are '/'-joined paths.  Arrays are gathered to host (np.asarray) — at
real scale this would be a per-shard async write; the format keeps that
extension trivial (one npz per host).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, state: Any, step: int = 0) -> None:
    flat = _flatten({"state": state, "meta": {"step": np.asarray(step)}})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str):
    with np.load(path) as f:
        flat = {k: f[k] for k in f.files}
    tree = _unflatten(flat)
    step = int(tree["meta"]["step"])
    return tree["state"], step
