"""Serving: prefill + one-token decode steps under auto (GSPMD) sharding.

OTA-DSGD is a training-time technique; serving has no gradient aggregation
(docs/DESIGN.md §5), so serve steps are plain jit with declarative shardings:
params over 'model', batch over the data axes, KV caches over
(batch -> data, heads-or-seq -> model).

Serve-while-train (docs/DESIGN.md §5, docs/EXPERIMENTS.md): the streamed
federated trainer (``train/fedllm.py``) hands each round's decoded global
params to :meth:`ServeStep.publish` — a jitted identity with
``out_shardings`` pinned to the serve placement and the input donated, so
the swap is a device-side relayout (an alias when the trainer already
produced the serve layout) with no host round-trip.  ``decode_fn`` keeps
answering requests against whichever published tree the caller holds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.sharding.specs import named_sharding_tree, param_specs
from repro.train.trainer import abstract_params


def _cache_leaf_spec(shape, data_axes, axis_sizes) -> P:
    """(layers, B, ...) cache leaf: B over data axes, one inner dim -> model."""
    model = axis_sizes.get("model", 1)
    data = int(np.prod([axis_sizes[a] for a in data_axes])) if data_axes else 1
    spec = [None] * len(shape)
    if len(shape) >= 2 and data > 1 and shape[1] % data == 0:
        spec[1] = data_axes if len(data_axes) > 1 else data_axes[0]
    if model > 1:
        for dim in range(2, len(shape)):
            if shape[dim] % model == 0 and shape[dim] >= model:
                spec[dim] = "model"
                break
    return P(*spec)


@dataclasses.dataclass
class ServeStep:
    arch: ArchConfig
    mesh: Any
    batch: int
    max_len: int
    decode_window: Optional[int]
    param_sharding: Any
    cache_sharding: Any
    decode_fn: Any          # jit'd (params, cache, token, pos) -> logits, cache
    prefill_fn: Any = None  # jit'd (params, cache, tokens) -> logits, cache
    publish_fn: Any = None  # jit'd identity onto param_sharding (donated)

    def init_cache(self, dtype=jnp.bfloat16):
        return model_lib.init_decode_cache(self.arch, self.batch,
                                           self.max_len, dtype,
                                           self.decode_window)

    def publish(self, params):
        """Swap a freshly decoded global param tree into the serve layout.

        The input is donated: when the trainer already produced the serve
        sharding (the single-mesh fedllm loop) this is a pure buffer alias;
        otherwise XLA reshards device-to-device.  Either way no host copy.
        The caller must treat its argument as consumed and serve from the
        returned tree.
        """
        return self.publish_fn(params)


def make_serve_step(arch: ArchConfig, mesh, batch: int, max_len: int,
                    decode_window: Optional[int] = None,
                    compute_dtype=jnp.bfloat16,
                    cache_dtype=jnp.bfloat16) -> ServeStep:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    model_size = axis_sizes.get("model", 1)
    aparams = abstract_params(arch)
    pspecs = param_specs(aparams, model_size)
    ns = lambda s: named_sharding_tree(mesh, s)            # noqa: E731
    param_sh = ns(pspecs)

    acache = jax.eval_shape(
        lambda: model_lib.init_decode_cache(arch, batch, max_len,
                                            cache_dtype, decode_window))
    cache_sh = jax.tree.map(
        lambda lf: ns(_cache_leaf_spec(lf.shape, data_axes, axis_sizes)),
        acache)
    tok_spec = ns(P(data_axes if len(data_axes) > 1 else data_axes[0])
                  if batch % max(int(np.prod([axis_sizes[a] for a in data_axes])), 1) == 0
                  and len(data_axes) else P())

    enc_sh = tok_spec if arch.encoder is not None else None  # batch over data

    def decode(params, cache, token, pos, *args):
        enc_out = args[0] if args else None
        logits, new_cache = model_lib.decode_step(
            params, arch, token, cache, pos, enc_out=enc_out,
            compute_dtype=compute_dtype, decode_window=decode_window)
        return logits, new_cache

    def prefill(params, cache, tokens, *args):
        # scan one decode step per prompt position: arch-generic (every
        # model family defines decode_step; the batched-forward fast path
        # is a per-family optimisation this contract leaves open) and one
        # compile regardless of prompt length
        enc_out = args[0] if args else None

        def body(cache, i):
            tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
            logits, cache = model_lib.decode_step(
                params, arch, tok, cache, i, enc_out=enc_out,
                compute_dtype=compute_dtype, decode_window=decode_window)
            return cache, logits
        cache, logits = jax.lax.scan(body, cache,
                                     jnp.arange(tokens.shape[1]))
        return logits[-1], cache

    in_sh = [param_sh, cache_sh, tok_spec, ns(P())]
    pre_sh = [param_sh, cache_sh, tok_spec]
    if arch.encoder is not None:
        in_sh.append(enc_sh)
        pre_sh.append(enc_sh)
    decode_fn = jax.jit(decode, in_shardings=tuple(in_sh),
                        out_shardings=(None, cache_sh),
                        donate_argnums=(1,))
    prefill_fn = jax.jit(prefill, in_shardings=tuple(pre_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
    publish_fn = jax.jit(lambda p: p, out_shardings=param_sh,
                         donate_argnums=(0,))
    return ServeStep(arch=arch, mesh=mesh, batch=batch, max_len=max_len,
                     decode_window=decode_window, param_sharding=param_sh,
                     cache_sharding=cache_sh, decode_fn=decode_fn,
                     prefill_fn=prefill_fn, publish_fn=publish_fn)
