"""Streamed OTA-DSGD over a real (sharded) LLM parameter tree.

The paper's federated round aggregates one d = 7850 vector; here the same
registered ``Scheme`` contract runs over the gradient pytree of any model
in the zoo (``repro/models``), streamed through the bandwidth-limited MAC
in fixed-size chunks (docs/DESIGN.md §13, docs/EXPERIMENTS.md):

* the param tree is flattened with the stable ``ravel_pytree`` leaf
  ordering (``train/trainer.py:ravel_meta``) — every device and the PS
  agree on which entry lands in which chunk;
* each chunk is one paper round of the registered scheme: per-device
  error-feedback accumulators persist *per chunk* across global rounds
  (the EF state is ``(n_chunks, m, chunk_len)``), so sparsification error
  in chunk ``i`` of round ``t`` is re-fed into chunk ``i`` of round
  ``t+1`` exactly as the MNIST-scale drivers do for their single vector;
* chunks are double-buffered: while the PS runs the AMP/decode of chunk
  ``i-1``, the devices encode + transmit chunk ``i``
  (``core.schemes.encode_round`` is the encode/MAC half split out of
  ``round_simulated``), as a ``jax.lax.scan`` whose carry is the
  in-flight MAC output — the dataflow XLA needs to overlap device
  compute with channel decode;
* per-chunk RNG is ``fold_in(fold_in(round_key, SALT_STREAM), chunk)``:
  derived from the round key, never from carried state, which keeps
  checkpoint/resume bitwise.

:class:`CompiledFedLLM` implements the ``carry0`` / ``run_segment``
segment contract, so :func:`repro.experiments.engine.run_checkpointed`
drives mid-sweep checkpoint/resume unchanged.  :func:`serve_while_train`
is the demo loop: every round's decoded globals are published into the
``ServeStep`` param sharding (donated-buffer swap) while ``decode_fn``
answers requests between rounds.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, OTAConfig, TrainConfig
from repro.core.schemes import (MACContext, Scheme, encode_round,
                                get_scheme, round_simulated)
from repro.models import model as model_lib
from repro.optim.optim import make_optimizer
from repro.train.trainer import _pad_multiple, abstract_params, ravel_meta

# RNG salts (extending the 0-7 layout in docs/ARCHITECTURE.md): chunk
# index inside a streamed round, and per-device synthetic-batch draws.
SALT_STREAM = 8
SALT_DATA = 9


def _chunk_key(key: jnp.ndarray, i) -> jnp.ndarray:
    """Per-chunk round key: chunk i is an independent paper round."""
    return jax.random.fold_in(jax.random.fold_in(key, SALT_STREAM), i)


def _chunk_metrics(metrics: Dict[str, jnp.ndarray], draw) -> Dict[str, Any]:
    """The per-chunk metric dict ``round_simulated`` would have produced."""
    met = {k: jnp.mean(v) for k, v in metrics.items()}
    met["active_frac"] = jnp.mean(draw.active.astype(jnp.float32))
    if draw.gain is not None:
        met["chan_gain"] = jnp.mean(draw.gain)
    if draw.noise_scale is not None:
        met["noise_scale"] = draw.noise_scale
    return met


def stream_round(scheme: Scheme, gchunks: jnp.ndarray, deltas: jnp.ndarray,
                 t, key: jnp.ndarray, ctx: MACContext):
    """One federated round streamed chunk-by-chunk, double-buffered.

    ``gchunks``/``deltas``: (n_chunks, m, chunk_len).  Pipeline shape:
    the prologue encodes chunk 0; each scan iteration decodes the
    in-flight chunk ``i-1`` while encoding chunk ``i`` (one body, two
    independent dataflows — XLA overlaps them); the epilogue decodes the
    last chunk.  Bitwise-equal to :func:`stream_round_ref` (the straight
    per-chunk ``round_simulated`` loop) because every chunk sees exactly
    the same ops with the same ``_chunk_key``; only the schedule differs.

    Returns ``(ghats, new_deltas, mets)`` stacked over chunks.
    """
    n_chunks = gchunks.shape[0]
    y0, nd0, met0, draw0 = encode_round(scheme, gchunks[0], deltas[0], t,
                                        _chunk_key(key, 0), ctx)
    met0 = _chunk_metrics(met0, draw0)

    def body(y_prev, inp):
        i, g_i, dl_i = inp
        ghat_prev = scheme.decode(y_prev, t, ctx)      # PS: chunk i-1
        y_i, nd_i, met_i, draw_i = encode_round(       # devices: chunk i
            scheme, g_i, dl_i, t, _chunk_key(key, i), ctx)
        return y_i, (ghat_prev, nd_i, _chunk_metrics(met_i, draw_i))

    idx = jnp.arange(1, n_chunks)
    y_last, (ghats_head, nds_tail, mets_tail) = jax.lax.scan(
        body, y0, (idx, gchunks[1:], deltas[1:]))
    ghat_last = scheme.decode(y_last, t, ctx)
    ghats = jnp.concatenate([ghats_head, ghat_last[None]], axis=0)
    new_deltas = jnp.concatenate([nd0[None], nds_tail], axis=0)
    mets = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b], axis=0),
                        met0, mets_tail)
    return ghats, new_deltas, mets


def stream_round_ref(scheme: Scheme, gchunks: jnp.ndarray,
                     deltas: jnp.ndarray, t, key: jnp.ndarray,
                     ctx: MACContext):
    """Non-pipelined reference: chunk i is literally ``round_simulated``
    under ``_chunk_key(key, i)``.  The parity pin for :func:`stream_round`
    (tests/test_fedllm.py)."""
    def body(_, inp):
        i, g_i, dl_i = inp
        ghat, nd, met = round_simulated(scheme, g_i, dl_i, t,
                                        _chunk_key(key, i), ctx)
        return None, (ghat, nd, met)

    idx = jnp.arange(gchunks.shape[0])
    _, (ghats, nds, mets) = jax.lax.scan(body, None, (idx, gchunks, deltas))
    return ghats, nds, mets


def stream_round_masked(scheme: Scheme, gchunks: jnp.ndarray,
                        deltas: jnp.ndarray, t, key: jnp.ndarray,
                        mask: jnp.ndarray, ctx: MACContext):
    """Masked-cohort variant: chunk i runs ``round_masked`` (participation
    masks, fault traces, guardrail metrics) with the same per-chunk keys.
    Not pipelined — the masked driver owns its own draw/fault plumbing;
    at the all-ones mask it is pinned bitwise to ``round_simulated`` and
    hence to :func:`stream_round`."""
    from repro.experiments.engine import round_masked

    def body(_, inp):
        i, g_i, dl_i = inp
        ghat, nd, met = round_masked(scheme, g_i, dl_i, t,
                                     _chunk_key(key, i), mask, ctx)
        return None, (ghat, nd, met)

    idx = jnp.arange(gchunks.shape[0])
    _, (ghats, nds, mets) = jax.lax.scan(body, None, (idx, gchunks, deltas))
    return ghats, nds, mets


@dataclasses.dataclass
class CompiledFedLLM:
    """Streamed federated rounds over a zoo model, segment-contract shaped.

    M simulated edge devices each draw a deterministic synthetic batch
    (``fold_in(round_key, SALT_DATA)`` split per device — nothing consumed
    from carried state), compute a local gradient, and stream the
    flattened tree through the OTA channel ``chunk_len`` entries at a
    time.  The PS unravels the concatenated decoded chunks and applies
    the optimizer.  ``run_segment`` scans rounds from an explicit carry,
    so :func:`repro.experiments.engine.run_checkpointed` checkpoints and
    resumes it bitwise.
    """
    arch: ArchConfig
    train_cfg: TrainConfig
    ota: OTAConfig
    m: int = 4
    batch: int = 2
    seq_len: int = 16
    chunk_size: int = 1 << 14
    seed: int = 0

    def __post_init__(self):
        aparams = abstract_params(self.arch)
        self.d, self.unravel = ravel_meta(aparams)
        unit = (self.ota.block_size if self.ota.projection == "blocked"
                else 1)
        self.chunk_len = _pad_multiple(max(min(self.chunk_size, self.d), 2),
                                       unit)
        self.n_chunks = -(-self.d // self.chunk_len)
        self.d_pad = self.n_chunks * self.chunk_len
        self.scheme = get_scheme(self.ota, self.chunk_len, self.m)
        self.ctx = MACContext(m=self.m, fading=self.ota.fading,
                              csi=self.scheme.csi,
                              use_kernel=self.ota.use_kernel)
        self.opt = make_optimizer(self.train_cfg)
        self.compute_dtype = jnp.dtype(self.train_cfg.compute_dtype)

    # ------------------------------------------------------------- carry
    def carry0(self) -> Tuple:
        params = model_lib.init_params(self.arch,
                                       jax.random.PRNGKey(self.seed))
        deltas = jnp.zeros((self.n_chunks, self.m, self.chunk_len),
                           jnp.float32)
        return (params, self.opt.init(params), deltas)

    _carry0 = carry0  # legacy spelling of the segment contract

    # ------------------------------------------------------------- round
    def _device_batch(self, key: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        cfg = self.arch
        b = {"tokens": jax.random.randint(key, (self.batch, self.seq_len),
                                          0, cfg.vocab)}
        if cfg.mrope_sections is not None:
            p = cfg.n_vision_tokens
            b["extra"] = 0.02 * jax.random.normal(
                key, (self.batch, p, cfg.d_model))
            b["positions"] = jnp.broadcast_to(
                jnp.arange(p + self.seq_len)[None, :, None],
                (self.batch, p + self.seq_len, 3)).astype(jnp.int32)
        if cfg.encoder is not None:
            b["frames"] = 0.02 * jax.random.normal(
                key, (self.batch, cfg.encoder.n_frames,
                      cfg.encoder.d_model))
        return b

    def _grads(self, params, key: jnp.ndarray):
        """(m, d_pad) per-device flat gradients + mean local loss.

        ``lax.map`` over devices: one device's activations live at a
        time — the (m, d_pad) gradient block is the only m-sized buffer.
        """
        def one(dev_key):
            batch = self._device_batch(dev_key)

            def local_loss(p):
                return model_lib.loss_fn(p, self.arch, batch,
                                         compute_dtype=self.compute_dtype,
                                         remat=self.train_cfg.remat)
            (loss, _), grads = jax.value_and_grad(
                local_loss, has_aux=True)(params)
            gflat, _ = jax.flatten_util.ravel_pytree(grads)
            gflat = jnp.pad(gflat.astype(jnp.float32),
                            (0, self.d_pad - self.d))
            return gflat, loss

        dev_keys = jax.random.split(
            jax.random.fold_in(key, SALT_DATA), self.m)
        gflat, losses = jax.lax.map(one, dev_keys)
        return gflat, jnp.mean(losses)

    def _round(self, sch: Scheme, carry, t, key, mask):
        params, opt_state, deltas = carry
        gflat, loss = self._grads(params, key)
        gchunks = gflat.reshape(self.m, self.n_chunks,
                                self.chunk_len).transpose(1, 0, 2)
        if mask is None:
            ghats, new_deltas, mets = stream_round(sch, gchunks, deltas,
                                                   t, key, self.ctx)
        else:
            ghats, new_deltas, mets = stream_round_masked(
                sch, gchunks, deltas, t, key, mask, self.ctx)
        ghat = ghats.reshape(self.d_pad)[: self.d]
        params, opt_state = self.opt.apply(params, self.unravel(ghat),
                                           opt_state)
        out = {"loss": loss,
               "metrics": {k: jnp.mean(v) for k, v in mets.items()}}
        return (params, opt_state, new_deltas), out

    # ------------------------------------------------------- traced entry
    def run_segment(self, overrides: Dict[str, jnp.ndarray],
                    keys: jnp.ndarray, mask, carry, t0):
        """Scan rounds ``t0 .. t0 + len(keys)`` from an explicit carry;
        returns ``(carry, outs)`` — the checkpoint/resume building block
        (same contract as ``CompiledExperiment.run_segment``)."""
        sch = (self.scheme.with_overrides(**overrides) if overrides
               else self.scheme)

        def body(carry, inp):
            t, key = inp
            return self._round(sch, carry, t, key, mask)

        ts = t0 + jnp.arange(keys.shape[0])
        return jax.lax.scan(body, carry, (ts, keys))

    def run(self, keys: jnp.ndarray,
            overrides: Optional[Dict[str, jnp.ndarray]] = None):
        """One full (jitted) run from the initial carry."""
        seg = jax.jit(lambda ov, k, c, t: self.run_segment(ov, k, None,
                                                           c, t))
        carry, outs = seg(overrides or {}, keys, self.carry0(),
                          jnp.int32(0))
        outs["params"] = carry[0]
        return outs


def serve_while_train(arch: ArchConfig, rounds: int = 2, *,
                      ota: Optional[OTAConfig] = None,
                      train_cfg: Optional[TrainConfig] = None,
                      m: int = 4, batch: int = 2, seq_len: int = 16,
                      chunk_size: int = 1 << 14,
                      serve_batch: int = 2, prompt_len: int = 4,
                      decode_steps: int = 4, seed: int = 0,
                      mesh=None, checkpoint_dir: Optional[str] = None,
                      checkpoint_every: int = 0, resume: bool = False,
                      verify_publish: bool = True) -> Dict[str, Any]:
    """The serve-while-train demo loop.

    Alternates one-round training segments with serving: after round
    ``t`` the decoded global params are :meth:`ServeStep.publish`-ed into
    the serve sharding (donated device-side swap) and ``decode_fn``
    answers a prefill + ``decode_steps`` greedy batch before round
    ``t+1`` starts.  With ``checkpoint_dir`` the carry snapshots every
    ``checkpoint_every`` rounds through ``train/checkpoint.py`` and
    ``resume=True`` continues bitwise (per-round keys are absolute, the
    carry is explicit).

    Returns ``{"losses", "metrics", "served_tokens", "publish_bitwise",
    "params"}``; ``publish_bitwise`` stays True iff every round's served
    params were bitwise-equal to that round's decoded globals
    (``verify_publish``; the acceptance pin).
    """
    from repro.experiments.engine import round_keys
    from repro.launch.mesh import make_local_mesh
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    from repro.train.serve import make_serve_step

    ota = ota or OTAConfig(projection="blocked", s_frac=0.25, k_frac=0.5,
                           block_size=1024)
    train_cfg = train_cfg or TrainConfig()
    mesh = mesh or make_local_mesh()
    fed = CompiledFedLLM(arch, train_cfg, ota, m=m, batch=batch,
                         seq_len=seq_len, chunk_size=chunk_size, seed=seed)
    serve = make_serve_step(arch, mesh, serve_batch,
                            prompt_len + decode_steps)
    keys = round_keys(rounds, seed)
    seg = jax.jit(lambda k, c, t: fed.run_segment({}, k, None, c, t))
    dev_copy = jax.jit(lambda p: jax.tree.map(jnp.copy, p))

    carry, t0 = fed.carry0(), 0
    ckpt = (os.path.join(checkpoint_dir, "fedllm_ckpt.npz")
            if checkpoint_dir else None)
    if resume and ckpt and os.path.exists(ckpt):
        loaded, t0 = load_checkpoint(ckpt)
        carry = jax.tree.unflatten(jax.tree.structure(carry),
                                   jax.tree.leaves(loaded))

    prompt = jnp.zeros((serve_batch, prompt_len), jnp.int32)
    losses, mets, served, publish_ok = [], [], [], True
    for t in range(t0, rounds):
        carry, outs = seg(keys[t:t + 1], carry, jnp.int32(t))
        losses.append(float(outs["loss"][0]))
        mets.append({k: float(v[0]) for k, v in outs["metrics"].items()})

        # publish round t's decoded globals (device-side copy so the
        # trainer's live carry is not donated away), then serve from them
        view = serve.publish(dev_copy(carry[0]))
        if verify_publish:
            same = all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(view),
                                jax.tree.leaves(carry[0])))
            publish_ok = publish_ok and same
        logits, cache = serve.prefill_fn(view, serve.init_cache(), prompt)
        toks = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(
            jnp.int32)
        for i in range(decode_steps):
            toks.append(np.asarray(tok)[:, 0])
            logits, cache = serve.decode_fn(view, cache, tok,
                                            jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(
                jnp.int32)
        served.append(np.stack(toks, axis=1))

        if ckpt and checkpoint_every and (t + 1) % checkpoint_every == 0:
            save_checkpoint(ckpt, jax.tree.map(np.asarray, carry),
                            step=t + 1)

    return {"losses": np.asarray(losses), "metrics": mets,
            "served_tokens": served, "publish_bitwise": publish_ok,
            "params": carry[0]}
