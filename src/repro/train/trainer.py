"""Distributed train step: two partial-manual shard_map phases + auto update.

Phase 1 (manual = ota_axes, auto = rest): per-OTA-device gradients — the loss
is the LOCAL batch mean, so no cross-device reduction happens implicitly; the
gradient pytree is flattened to a padded d-vector sharded over the auto axes.

Phase 2 (manual = ota_axes + shard axes): the scheme's aggregation pipeline
on gradient *slices* — every device owns d_pad / n_shards entries of its
replica's vector, nothing d-sized is replicated or gathered.  The scheme is
resolved from the registry (repro.core.schemes.get_scheme) and run by the
generic slice driver (core/distributed.sharded_round) under a MACContext
describing the placement.  The MAC superposition is the psum over ota_axes;
AWGN is injected once per channel slice.

Phase 3 (auto): unravel ghat and apply the optimizer under GSPMD.

The error accumulator Delta is carried as a (M_1..M_k, d_pad) array split
over the manual axes and sharded over the auto axes along d — the paper's
M x d error-feedback memory is explicit, placed, and visible to the dry-run.

``ota_axes=('data',)`` (or ('pod','data')) maps one edge device per data
coordinate; ``ota_axes=('pod',)`` is the hierarchical "edge site" variant:
intra-pod aggregation is the ideal mean (emerges from auto data-parallel
grads), the MAC runs across pods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, OTAConfig, TrainConfig
from repro.core import distributed
from repro.core.schemes import MACContext, get_scheme
from repro.models import model as model_lib
from repro.optim.optim import make_optimizer
from repro.sharding import constrain, shard_map
from repro.sharding.specs import named_sharding_tree, param_specs


def _pad_multiple(d: int, m: int) -> int:
    return -(-d // m) * m


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def ravel_meta(aparams):
    """``(d, unravel)`` for an abstract param tree: total parameter count
    and the flat-vector -> pytree unraveller with a *stable leaf ordering*
    (ravel_pytree's canonical flatten order — the contract the streamed
    fedllm driver and the flat trainer layout both rely on: every device
    and the PS agree on which gradient entry lands in which chunk).

    The unraveller is built from an eval_shape tree via closure over
    abstract zeros, so nothing d-sized is materialised here.
    """
    d = int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(aparams)))

    def unravel(flat):
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aparams)
        _, unr = jax.flatten_util.ravel_pytree(zeros)
        return unr(flat)

    return d, unravel


@dataclasses.dataclass
class TrainStep:
    arch: ArchConfig
    train: TrainConfig
    ota: OTAConfig
    ota_axes: Tuple[str, ...]
    mesh: Any
    m_devices: int
    d: int
    d_pad: int
    delta_shape: Tuple[int, ...]
    delta_sharding: Any
    param_sharding: Any
    opt_sharding: Any
    batch_spec: Any
    _jit_cache: Dict[Any, Any] = dataclasses.field(default_factory=dict)
    _builder: Any = None

    def jitted(self, batch_tree):
        sig = tuple(sorted(batch_tree.keys()))
        if sig not in self._jit_cache:
            self._jit_cache[sig] = self._builder(batch_tree)
        return self._jit_cache[sig]

    def init_state(self, key):
        opt = make_optimizer(self.train)
        params = model_lib.init_params(self.arch, key)
        opt_state = opt.init(params)
        delta = jnp.zeros(self.delta_shape, jnp.dtype(self.ota.state_dtype))
        return params, opt_state, delta


def make_train_step(arch: ArchConfig, train_cfg: TrainConfig, ota: OTAConfig,
                    mesh, ota_axes: Sequence[str] = ("data",),
                    donate: bool = True, loss_chunk: int = 2048) -> TrainStep:
    ota_axes = tuple(ota_axes)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m_manual = int(np.prod([axis_sizes[a] for a in ota_axes]))
    auto_axes = tuple(a for a in mesh.axis_names if a not in ota_axes)
    model_size = axis_sizes.get("model", 1)
    n_shards = int(np.prod([axis_sizes[a] for a in auto_axes])) if auto_axes else 1

    aparams = abstract_params(arch)
    d, unravel = ravel_meta(aparams)
    pad_unit = (ota.block_size * n_shards if ota.projection == "blocked"
                else max(n_shards, 1))
    d_pad = _pad_multiple(d, max(pad_unit, 1))

    groups = None
    m_eff = m_manual
    if ota.num_groups and ota.num_groups < m_manual:
        # the grouped psum runs over the LAST manual axis only (psum with
        # axis_index_groups is per-axis); distribute the requested group
        # count across the other manual axes (e.g. pods)
        m_last = axis_sizes[ota_axes[-1]]
        other = m_manual // m_last
        npg = max(1, ota.num_groups // other)
        gs = m_last // npg
        groups = [[g * gs + i for i in range(gs)] for g in range(npg)]
        m_eff = npg * other
    opt = make_optimizer(train_cfg)
    compute_dtype = jnp.dtype(train_cfg.compute_dtype)
    scheme = get_scheme(ota, d_pad, m_eff)
    agg_ctx = MACContext(
        m=m_eff, device_axes=ota_axes, shard_axes=auto_axes,
        groups=(tuple(tuple(g) for g in groups) if groups is not None
                else None),
        fading=ota.fading, csi=scheme.csi, d_pad=d_pad,
        frame_dtype=(jnp.dtype(ota.frame_dtype)
                     if ota.frame_dtype != "float32" else None),
        shard_decode=ota.shard_decode, use_kernel=ota.use_kernel)
    inner_spec = P(auto_axes) if auto_axes else P()

    # ---------------- phase 1: per-device grads ---------------------------
    def grads_body(params, batch):
        def local_loss(p):
            return model_lib.loss_fn(p, arch, batch,
                                     compute_dtype=compute_dtype,
                                     remat=train_cfg.remat,
                                     loss_chunk=loss_chunk)
        (loss, metrics), grads = jax.value_and_grad(local_loss,
                                                    has_aux=True)(params)
        gflat, _ = jax.flatten_util.ravel_pytree(grads)
        gflat = jnp.pad(gflat.astype(jnp.float32), (0, d_pad - d))
        gflat = constrain(gflat, mesh, inner_spec)
        loss_g = loss
        for ax in ota_axes:
            loss_g = jax.lax.psum(loss_g, ax)
        gflat = gflat.reshape((1,) * len(ota_axes) + (d_pad,))
        return gflat, dict(metrics, global_loss=loss_g / m_manual)

    # ---------------- phase 2: OTA aggregation on slices ------------------
    def agg_body(gflat_slice, delta_slice, step, key):
        ghat, new_delta, metrics = distributed.sharded_round(
            scheme, gflat_slice.reshape(-1), delta_slice.reshape(-1),
            step, key, agg_ctx)
        return (ghat.reshape(gflat_slice.shape),
                new_delta.reshape(delta_slice.shape), metrics)

    manual1 = set(ota_axes)
    manual2 = set(ota_axes) | set(auto_axes)
    pspecs = param_specs(aparams, model_size)
    opt_abstract = jax.eval_shape(opt.init, aparams)
    ospecs = {k: (pspecs if k in ("m", "v") else P())
              for k in opt_abstract}
    delta_spec_full = P(*ota_axes, auto_axes if auto_axes else None)
    batch_spec = P(ota_axes)
    # jit-level batch sharding also spreads over auto data-like axes
    batch_jit_spec = P(ota_axes + tuple(a for a in auto_axes if a != "model"))
    ns = lambda s: NamedSharding(mesh, s)                       # noqa: E731
    param_sh = named_sharding_tree(mesh, pspecs)
    opt_sh = named_sharding_tree(mesh, ospecs)
    delta_sh = ns(delta_spec_full)
    rep = lambda t: jax.tree.map(lambda _: P(), t)              # noqa: E731

    def builder(batch_tree):
        phase1 = shard_map(
            grads_body, mesh=mesh,
            in_specs=(rep(aparams),
                      jax.tree.map(lambda _: batch_spec, batch_tree)),
            out_specs=(P(*ota_axes, None), P()),
            axis_names=manual1, check_vma=False)
        phase2 = shard_map(
            agg_body, mesh=mesh,
            in_specs=(delta_spec_full, delta_spec_full, P(), P()),
            out_specs=(P(None, auto_axes if auto_axes else None),
                       delta_spec_full, P()),
            axis_names=manual2, check_vma=False)

        def step_fn(params, opt_state, delta, batch, step, key):
            gstacked, metrics = phase1(params, batch)
            gstacked = gstacked.reshape(
                tuple(axis_sizes[a] for a in ota_axes) + (d_pad,))
            gstacked = jax.lax.with_sharding_constraint(
                gstacked, ns(delta_spec_full))
            ghat_s, new_delta, agg_metrics = phase2(
                gstacked, delta, step, key)
            ghat = ghat_s.reshape(d_pad)
            ghat = jax.lax.with_sharding_constraint(
                ghat, ns(P(auto_axes) if auto_axes else P()))
            ghat_tree = unravel(ghat[:d])
            params, opt_state = opt.apply(params, ghat_tree, opt_state)
            return params, opt_state, new_delta, {**metrics, **agg_metrics}

        in_sh = (param_sh, opt_sh, delta_sh,
                 jax.tree.map(lambda _: ns(batch_jit_spec), batch_tree),
                 ns(P()), ns(P()))
        jfn = jax.jit(step_fn, in_shardings=in_sh,
                      out_shardings=(param_sh, opt_sh, delta_sh, None),
                      donate_argnums=(0, 1, 2) if donate else ())
        return jfn

    # phase-2 slice layout: (M_1..M_k, d_pad) where the last dim shards over
    # auto axes; the shard_map in_spec P(*ota_axes, auto) slices both.
    delta_shape = tuple(axis_sizes[a] for a in ota_axes) + (d_pad,)
    return TrainStep(arch=arch, train=train_cfg, ota=ota, ota_axes=ota_axes,
                     mesh=mesh, m_devices=m_eff, d=d, d_pad=d_pad,
                     delta_shape=delta_shape, delta_sharding=delta_sh,
                     param_sharding=param_sh, opt_sharding=opt_sh,
                     batch_spec=batch_spec, _builder=builder)


# ===========================================================================
# "sliced" layout (§Perf optimisation O1): slice-local leafwise aggregation
# ===========================================================================
#
# The flat layout pays ~3x d bytes of all-gather/collective-permute per step
# re-laying param-sharded gradient leaves into a linearly-sharded d-vector
# and back.  The OTA pipeline never needed a canonical element order: top-k
# is order-free and the block-diagonal projection indexes blocks by id.  So
# define the d-vector as "concatenation of each model shard's local leaf
# pieces": every device flattens ITS OWN gradient pieces — zero d-sized
# collectives remain; the only cross-device traffic is the s-sized MAC psum
# and scalar coordination.
#
# Leaves replicated over 'model' (norm gains, non-divisible embeddings) are
# aggregated by a second, shard-replicated OTA sub-frame with its own power
# share; both sub-frames satisfy sum = P_t.


def _classify_leaves(aparams, pspecs):
    """Returns (paths, specs, sharded_mask, sizes_local, sizes_rep)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(aparams)
    sflat = jax.tree.leaves(pspecs)
    info = []
    for (path, leaf), spec in zip(flat, sflat):
        sharded = any(e == "model" for e in spec)
        info.append((path, leaf, spec, sharded))
    return info, treedef


def make_train_step_sliced(arch: ArchConfig, train_cfg: TrainConfig,
                           ota: OTAConfig, mesh,
                           ota_axes: Sequence[str] = ("data",),
                           donate: bool = True,
                           loss_chunk: int = 2048) -> "TrainStep":
    ota_axes = tuple(ota_axes)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m_manual = int(np.prod([axis_sizes[a] for a in ota_axes]))
    auto_axes = tuple(a for a in mesh.axis_names if a not in ota_axes)
    assert auto_axes == ("model",), (
        "sliced layout supports ota_axes covering all but the model axis")
    model_size = axis_sizes["model"]

    aparams = abstract_params(arch)
    pspecs = param_specs(aparams, model_size)
    info, treedef = _classify_leaves(aparams, pspecs)
    c = ota.block_size

    def local_size(leaf, spec, sharded):
        n = int(np.prod(leaf.shape))
        return n // model_size if sharded else n

    d_sh = sum(local_size(lf, s, sh) for _, lf, s, sh in info if sh)
    d_rep = sum(local_size(lf, s, sh) for _, lf, s, sh in info if not sh)
    d_sh_pad = _pad_multiple(max(d_sh, c), c)
    d_rep_pad = _pad_multiple(max(d_rep, c), c)
    d_total = d_sh * model_size + d_rep
    p_share_sh = (d_sh * model_size) / d_total
    d = int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(aparams)))

    groups = None
    m_eff = m_manual
    if ota.num_groups and ota.num_groups < m_manual:
        m_last = axis_sizes[ota_axes[-1]]
        other = m_manual // m_last
        npg = max(1, ota.num_groups // other)
        gs = m_last // npg
        groups = [[g * gs + i for i in range(gs)] for g in range(npg)]
        m_eff = npg * other

    opt = make_optimizer(train_cfg)
    compute_dtype = jnp.dtype(train_cfg.compute_dtype)
    frame_dtype = (jnp.dtype(ota.frame_dtype)
                   if ota.frame_dtype != "float32" else None)
    state_dtype = jnp.dtype(ota.state_dtype)
    scheme = get_scheme(ota, d_sh_pad * model_size + d_rep_pad, m_eff)
    groups_t = (tuple(tuple(g) for g in groups) if groups is not None
                else None)
    # two sub-frames: the model-sharded pieces and the replicated pieces,
    # each with its own power share (sum = P_t) and decorrelated RNG salt
    ctx_sh = MACContext(
        m=m_eff, device_axes=ota_axes, shard_axes=("model",),
        groups=groups_t, fading=ota.fading, csi=scheme.csi,
        d_pad=d_sh_pad * model_size,
        p_scale=p_share_sh, frame_dtype=frame_dtype,
        shard_decode=ota.shard_decode, use_kernel=ota.use_kernel)
    ctx_rep = MACContext(
        m=m_eff, device_axes=ota_axes, shard_axes=(),
        groups=groups_t, fading=ota.fading, csi=scheme.csi,
        d_pad=d_rep_pad,
        p_scale=1.0 - p_share_sh, key_salt=1789, frame_dtype=frame_dtype,
        shard_decode=ota.shard_decode, use_kernel=ota.use_kernel)

    # ---------------- phase 1: per-device grads (tree out) ----------------
    def grads_body(params, batch):
        def local_loss(p):
            return model_lib.loss_fn(p, arch, batch,
                                     compute_dtype=compute_dtype,
                                     remat=train_cfg.remat,
                                     loss_chunk=loss_chunk)
        (loss, metrics), grads = jax.value_and_grad(local_loss,
                                                    has_aux=True)(params)
        grads = jax.tree.map(
            lambda g, s: constrain(g.astype(jnp.float32), mesh, s),
            grads, pspecs)
        loss_g = loss
        for ax in ota_axes:
            loss_g = jax.lax.psum(loss_g, ax)
        grads = jax.tree.map(lambda g: g[None], grads)
        return grads, dict(metrics, global_loss=loss_g / m_manual)

    # ---------------- phase 2: slice-local OTA ----------------------------
    def _flatten_group(leaves):
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate([lf.reshape(-1) for lf in leaves])

    def agg_body(grads, delta_sh, delta_rep, step, key):
        leaves = jax.tree.leaves(grads)
        sh_leaves = [lf[0] for lf, (_, _, _, sh) in zip(leaves, info) if sh]
        rep_leaves = [lf[0]
                      for lf, (_, _, _, sh) in zip(leaves, info) if not sh]
        g_sh = jnp.pad(_flatten_group(sh_leaves), (0, d_sh_pad - d_sh))
        g_rep = jnp.pad(_flatten_group(rep_leaves), (0, d_rep_pad - d_rep))
        dl_sh = delta_sh.reshape(-1)
        dl_rep = delta_rep.reshape(-1)
        ghat_sh, nd_sh, met = distributed.sharded_round(
            scheme, g_sh, dl_sh, step, key, ctx_sh)
        ghat_rep, nd_rep, _ = distributed.sharded_round(
            scheme, g_rep, dl_rep, step, key, ctx_rep)
        # unflatten back into the gradient tree (local shapes)
        out, i_sh, i_rep = [], 0, 0
        p_sh, p_rep = ghat_sh[:d_sh], ghat_rep[:d_rep]
        for lf, (_, _, _, sh) in zip(leaves, info):
            shape = lf.shape[1:]
            n = int(np.prod(shape))
            if sh:
                out.append(p_sh[i_sh:i_sh + n].reshape(shape))
                i_sh += n
            else:
                out.append(p_rep[i_rep:i_rep + n].reshape(shape))
                i_rep += n
        ghat_tree = jax.tree.unflatten(jax.tree.structure(grads), out)
        return (ghat_tree,
                nd_sh.astype(state_dtype).reshape(delta_sh.shape),
                nd_rep.astype(state_dtype).reshape(delta_rep.shape), met)

    manual2 = set(ota_axes) | {"model"}
    ospecs = {k: (pspecs if k in ("m", "v") else P())
              for k in jax.eval_shape(opt.init, aparams)}
    ns = lambda s: NamedSharding(mesh, s)                   # noqa: E731
    param_sh = named_sharding_tree(mesh, pspecs)
    opt_sh = named_sharding_tree(mesh, ospecs)
    rep = lambda t: jax.tree.map(lambda _: P(), t)          # noqa: E731
    batch_spec = P(ota_axes)

    def _stacked_spec(spec):
        return P(ota_axes if len(ota_axes) > 1 else ota_axes[0], *spec)

    grads_specs = jax.tree.unflatten(
        treedef, [_stacked_spec(s) for _, _, s, _ in info])
    delta_sh_spec = P(*ota_axes, "model", None)
    delta_rep_spec = P(*ota_axes, None)
    dims = tuple(axis_sizes[a] for a in ota_axes)
    delta_sh_shape = dims + (model_size, d_sh_pad)
    delta_rep_shape = dims + (d_rep_pad,)

    def builder(batch_tree):
        phase1 = shard_map(
            grads_body, mesh=mesh,
            in_specs=(rep(aparams),
                      jax.tree.map(lambda _: batch_spec, batch_tree)),
            out_specs=(jax.tree.unflatten(
                treedef,
                [P(ota_axes if len(ota_axes) > 1 else ota_axes[0],
                   *([None] * len(lf.shape)))
                 for _, lf, _, _ in info]), P()),
            axis_names=set(ota_axes), check_vma=False)
        phase2 = shard_map(
            agg_body, mesh=mesh,
            in_specs=(grads_specs, delta_sh_spec, delta_rep_spec, P(), P()),
            out_specs=(jax.tree.unflatten(treedef,
                                          [P(*s) for _, _, s, _ in info]),
                       delta_sh_spec, delta_rep_spec, P()),
            axis_names=manual2, check_vma=False)

        def step_fn(params, opt_state, delta, batch, step, key):
            gstacked, metrics = phase1(params, batch)
            gstacked = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, ns(s)),
                gstacked, grads_specs)
            ghat_tree, nd_sh, nd_rep, met2 = phase2(
                gstacked, delta["sh"], delta["rep"], step, key)
            params, opt_state = opt.apply(params, ghat_tree, opt_state)
            return (params, opt_state, {"sh": nd_sh, "rep": nd_rep},
                    {**metrics, **met2})

        in_sh = (param_sh, opt_sh,
                 {"sh": ns(delta_sh_spec), "rep": ns(delta_rep_spec)},
                 jax.tree.map(lambda _: ns(batch_spec), batch_tree),
                 ns(P()), ns(P()))
        out_sh = (param_sh, opt_sh,
                  {"sh": ns(delta_sh_spec), "rep": ns(delta_rep_spec)}, None)
        return jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1, 2) if donate else ())

    ts = TrainStep(arch=arch, train=train_cfg, ota=ota, ota_axes=ota_axes,
                   mesh=mesh, m_devices=m_eff, d=d,
                   d_pad=d_sh_pad * model_size + d_rep_pad,
                   delta_shape=(delta_sh_shape, delta_rep_shape),
                   delta_sharding={"sh": ns(delta_sh_spec),
                                   "rep": ns(delta_rep_spec)},
                   param_sharding=param_sh, opt_sharding=opt_sh,
                   batch_spec=batch_spec, _builder=builder)

    def init_state(key):
        params = model_lib.init_params(arch, key)
        opt_state = opt.init(params)
        delta = {"sh": jnp.zeros(delta_sh_shape, state_dtype),
                 "rep": jnp.zeros(delta_rep_shape, state_dtype)}
        return params, opt_state, delta

    ts.init_state = init_state
    return ts
