from repro.sharding.specs import param_specs  # noqa: F401
