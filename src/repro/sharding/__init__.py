from repro.sharding.specs import param_specs  # noqa: F401

import jax


def shard_map(f, mesh, in_specs, out_specs, axis_names, check_vma=False):
    """Version-portable partial-manual shard_map.

    jax >= 0.5 exposes ``jax.shard_map(..., axis_names=, check_vma=)``;
    earlier releases only have ``jax.experimental.shard_map.shard_map`` where
    the manual axes are specified as the complement (``auto=``) and the
    replication check is ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def constrain(x, mesh, spec):
    """Version-portable with_sharding_constraint for bare PartitionSpecs.

    Newer jax resolves the mesh from the surrounding shard_map/jit scope;
    jax <= 0.4.x needs the mesh context manager to interpret a bare spec.
    """
    if hasattr(jax, "shard_map"):
        return jax.lax.with_sharding_constraint(x, spec)
    with mesh:
        return jax.lax.with_sharding_constraint(x, spec)
