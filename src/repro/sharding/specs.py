"""Parameter/activation PartitionSpecs.

Rule-based: for every param leaf, shard the widest dimension divisible by the
'model' axis size (skipping the leading layer-stack dimension of scanned
blocks); replicate otherwise.  MoE expert tensors shard the expert dim when
divisible (expert parallelism); embeddings/lm-head shard vocab.  Batch dims
of inputs/caches shard over the data axes (handled at the call sites).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


_STACKED_ROOTS = ("blocks", "encoder")


def _leaf_spec(path: str, shape, model_size: int, model_axis: str = "model"):
    if model_size <= 1 or len(shape) == 0:
        return P()
    start = 1 if any(f"'{r}'" in path or f"/{r}/" in path or
                     path.startswith(r) for r in _STACKED_ROOTS) else 0
    ndim = len(shape)
    # preferred dims: experts first (expert parallelism), then widest-last
    dims = list(range(start, ndim))
    # try from the last (usually output/ff) dim backwards
    for dim in sorted(dims, key=lambda i: (shape[i] % model_size == 0,
                                           shape[i]), reverse=True):
        if shape[dim] % model_size == 0 and shape[dim] >= model_size:
            spec = [None] * ndim
            spec[dim] = model_axis
            return P(*spec)
    return P()


def param_specs(params: Any, model_size: int, model_axis: str = "model"):
    """PartitionSpec pytree matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", None)
        if shape is None:
            shape = np.shape(leaf)
        specs.append(_leaf_spec(pstr, shape, model_size, model_axis))
    return jax.tree_util.tree_unflatten(treedef, specs)


def spec_tree_like(tree: Any, spec) -> Any:
    return jax.tree.map(lambda _: spec, tree)


def named_sharding_tree(mesh, specs: Any) -> Any:
    """Map a PartitionSpec pytree to NamedShardings on ``mesh``.

    The one-liner every jit caller was writing inline (serve, trainer,
    fedllm); ``is_leaf`` is pinned to PartitionSpec so the map stays
    correct even on jax versions where P registers as a pytree node.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
