"""Compiled experiment engine: a federated run as ONE jitted ``lax.scan``.

``train/paper_repro.run_federated`` is the reference implementation — a
Python loop dispatching one jitted round at a time, with host evals in
between.  This module compiles the *entire run* instead: the scan carry is
``(params, opt_state, deltas, momenta)``, each scan step performs the full
round (per-device gradients -> scheme encode -> MAC -> PS decode -> ADAM)
with the paper's per-round key stream, and test accuracy/loss are computed
inside the scan, so ``steps`` rounds cost one XLA dispatch and zero host
round-trips.  ``repro.experiments.sweep`` vmaps whole sweep grids over the
scan (see docs/DESIGN.md §6 for the traced/static split).

The round body is built from the same pieces as the reference loop
(``device_grads``, ``round_simulated``, ``Optimizer.apply``), which is what
the bitwise parity test in ``tests/test_experiments.py`` pins.

Device-count sweeps use :func:`round_masked`: M is a *shape*, so a vmapped
M-axis pads every grid point to ``M_pad`` devices and silences the padding
with a traced participation mask (docs/DESIGN.md §6 explains why padding,
not reshaping, is the only way to put M on a vmap axis).
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core import channel, scheduling
from repro.core import schemes as schemes_mod
from repro.core.schemes import MACContext, Scheme, get_scheme, round_simulated
from repro.local.work import (
    LOCAL_OVERRIDE_ATTRS, LocalWork, get_local, local_device_grads,
)
from repro.optim.optim import Optimizer
from repro.robust import aggregators, faults, guards
from repro.train.paper_repro import (
    accuracy, ce_loss, device_grads, flat_grad_fn, init_linear,
)

#: base of the per-round key stream; round t of seed 0 uses PRNGKey(1000 + t),
#: matching run_federated exactly (seed k shifts the stream by k * steps so
#: seed sweeps draw disjoint keys)
KEY_STREAM_BASE = 1000


def round_keys(steps: int, seed: int = 0) -> jnp.ndarray:
    """(steps, ...) stacked per-round PRNG keys for one run."""
    seeds = KEY_STREAM_BASE + seed * steps + jnp.arange(steps)
    return jax.vmap(jax.random.PRNGKey)(seeds)


def eval_indices(steps: int, eval_every: int) -> np.ndarray:
    """The rounds run_federated evaluates after (t % every == 0 or last)."""
    return np.asarray([t for t in range(steps)
                       if t % eval_every == 0 or t == steps - 1], np.int64)


@dataclass(frozen=True)
class Experiment:
    """Static description of one federated training configuration."""
    cfg: OTAConfig
    steps: int
    lr: float = 1e-3
    eval_every: int = 10
    optimizer: str = "adam"
    local_steps: int = 1
    local_lr: float = 0.1
    momentum_correction: float = 0.0
    seed: int = 0
    use_kernel: bool = False     # Pallas projection/AMP inside the scan
    guard: Optional[guards.GuardConfig] = None   # round guardrails (§10)


@dataclass
class EngineRun:
    """Result of one compiled run — mirrors FederatedRun at eval points."""
    accs: List[float]
    losses: List[float]
    metrics: List[Dict[str, float]]
    eval_steps: np.ndarray
    all_accs: np.ndarray         # (steps,) — every round, free inside scan
    all_losses: np.ndarray
    params: Any = None           # final model parameters (pytree)


# ---------------------------------------------------------------------------
# masked round (padded device-count sweeps)
# ---------------------------------------------------------------------------


def round_masked(scheme: Scheme, grads: jnp.ndarray, deltas: jnp.ndarray,
                 step, key: jnp.ndarray, mask: jnp.ndarray, ctx: MACContext,
                 *, dev_keys=None, draw=None, mac=None, fault=None,
                 sched=None):
    """:func:`~repro.core.schemes.round_simulated` with a traced device mask.

    ``mask`` (M_pad,) marks which padded devices exist at this grid point:
    masked-out devices transmit nothing (their frames — including the analog
    power/mean slots — are zeroed before the MAC sum), keep their error
    state untouched, and the PS decodes against the traced effective device
    count.  The RNG layout (key salts, ``split(key, M_pad)``) matches
    ``round_simulated`` at ``M = M_pad``, so an all-ones mask reproduces it
    exactly (masking multiplies frames by 1.0 and adds 0.0 to the sum).

    The keyword hooks re-seat the round on a sampled cohort
    (:mod:`repro.population`): ``dev_keys`` (M_pad, ...) replaces the
    in-place key split, ``draw`` replaces the channel realisation (the
    cohort view of a full-population draw), ``mac`` — a callable
    ``(frames, key, sigma2) -> y`` — replaces the flat analog MAC sum
    (hierarchical edge-site aggregation), and ``fault`` replaces the fault
    realisation (the cohort view of a full-population trace), and
    ``sched`` — a (M_pad,) bool transmit set from the subband scheduler
    (:mod:`repro.core.scheduling`) — restricts the round to the scheduled
    devices: an unscheduled device is treated exactly like a deep-faded
    one (its frame never reaches the MAC and its whole update banks via
    ``Scheme.silent_state``).  Defaults preserve the legacy path bitwise.

    Fault injection (:mod:`repro.robust`, docs/DESIGN.md §10) is gated on
    the *static* ``scheme.robust_on``: Byzantine/stale gradients transform
    before encode, NaN/Inf poisoning hits the encoded *frame* (a broken
    transmitter on the air interface — gradient-level NaN would be
    filtered structurally by top-k sparsification), dropouts leave the
    transmit set with error-feedback banking via ``Scheme.silent_state``,
    and digital packet erasures drop the frame while the unaware device
    banks nothing.  Robust aggregation gates on the static
    ``cfg.aggregator`` / ``cfg.clip_power`` — independent of fault
    injection, so defences can run without attacks and vice versa.
    """
    m_pad = grads.shape[0]
    mask_b = mask > 0
    # the max guard only engages when *every* device is masked out (an
    # empty cohort round); any populated mask is untouched bitwise
    m_eff = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    ctx = dataclasses.replace(ctx, m=m_eff)
    if dev_keys is None:
        dev_keys = jax.random.split(jax.random.fold_in(key, 1), m_pad)
    if draw is None:
        # device-coupled draws (the blind PS combiner) must not see the
        # padded phantom devices' channels; an all-ones mask multiplies
        # rows by 1.0, so the unmasked equivalence below still holds bitwise
        draw = scheme.channel_draw(jax.random.fold_in(key, 2), step, m_pad,
                                   mask=mask_b)
    if sched is not None:
        # the scheduler's transmit set composes like a deep fade: the
        # frame is silenced and the analog silent_state banking below
        # catches the unscheduled device (digital banking is explicit)
        draw = draw._replace(active=draw.active & sched)
    robust = scheme.robust_on
    cfg = scheme.cfg
    true_grads = grads
    if robust:
        if fault is None:
            fault = scheme.fault_draw(
                jax.random.fold_in(key, faults.SALT_FAULT), step, m_pad)
        grads = faults.apply_gradient_faults(
            grads, fault, byz_attack=cfg.byz_attack,
            byz_scale=scheme.byz_scale)
    active = draw.active
    frames, new_deltas, metrics = jax.vmap(
        lambda g, dl, kk, pf: scheme.encode(g, dl, step, kk,
                                            ctx.with_p_factor(pf)))(
            grads, deltas, dev_keys, draw.p_factor)
    if scheme.analog:
        if robust:
            # make_frame normalises every frame to P_t, so an analog
            # attacker's leverage is transmit *power*, not gradient scale:
            # Byzantine frames violate the power constraint by byz_scale
            # in amplitude, and dropouts leave the transmit set mid-round
            byz_amp = jnp.where(fault.byz, scheme.byz_scale, 1.0)
            frames = frames * byz_amp[:, None].astype(frames.dtype)
            active = active & ~fault.dropout
        if cfg.clip_power:
            # transmit-side hardware cap: the analog defence (bounds the
            # power any device — honest or Byzantine — can put on the MAC)
            frames = aggregators.clip_frame_power(
                frames, scheme.power_cap * scheme.p_t(step))
        if robust:
            # after the clip: a power limiter cannot repair a broken DAC
            frames = faults.apply_frame_faults(frames, fault)
        new_deltas = jnp.where(active[:, None], new_deltas,
                               scheme.silent_state(true_grads, deltas,
                                                   new_deltas))
        active = active & mask_b
        frames = schemes_mod.apply_channel_gain(
            frames, draw._replace(active=active))
        mac_key = jax.random.fold_in(key, 0)
        sigma2 = schemes_mod.round_sigma2(scheme, draw)
        y = (channel.mac_sum(frames, mac_key, sigma2) if mac is None
             else mac(frames, mac_key, sigma2))
    else:
        if robust:
            # dropouts know they failed -> bank their whole update; erased
            # packets are lost in the channel and poisoned packets carry
            # garbage payloads — either way the unaware device's state
            # evolves as if sent
            frames = faults.apply_frame_faults(frames, fault)
            new_deltas = jnp.where(
                fault.dropout[:, None],
                scheme.silent_state(true_grads, deltas, new_deltas),
                new_deltas)
            active = active & ~fault.dropout & ~fault.erased
        if sched is not None:
            # an unscheduled digital device knows it was not granted a
            # subband this round and banks its whole update (EF over the
            # digital link, like a robust dropout that saw it coming)
            new_deltas = jnp.where(
                sched[:, None], new_deltas,
                scheme.silent_state(true_grads, deltas, new_deltas))
        active = active & mask_b
        if cfg.aggregator != "mean":
            y = aggregators.robust_combine(
                frames, active, m_eff, aggregator=cfg.aggregator,
                trim_frac=scheme.trim_frac, norm_cap=scheme.norm_cap)
        else:
            # the literal sum (never the trimmed path at trim=0: a sorted
            # sum re-associates, which is not bitwise the same reduction)
            frames = frames * (active if (robust or sched is not None)
                               else mask_b)[:, None]
            y = jnp.sum(frames, axis=0)
    # padded devices do not exist: their error state must not evolve
    new_deltas = jnp.where(mask_b[:, None], new_deltas, deltas)
    ghat = scheme.decode(y, step, ctx)
    w = mask.astype(jnp.float32)
    metrics = {k: jnp.sum(v * w) / m_eff for k, v in metrics.items()}
    metrics["active_frac"] = jnp.sum(active.astype(jnp.float32)) / m_eff
    if robust:
        faulty = fault.poison | fault.stale | fault.dropout | fault.erased
        metrics["byz_frac"] = (jnp.sum((fault.byz & mask_b)
                                       .astype(jnp.float32)) / m_eff)
        metrics["fault_frac"] = (jnp.sum((faulty & mask_b)
                                         .astype(jnp.float32)) / m_eff)
    return ghat, new_deltas, metrics


# ---------------------------------------------------------------------------
# the compiled runner
# ---------------------------------------------------------------------------


class CompiledExperiment:
    """Compile-once runner for one static configuration.

    :meth:`run` (and :meth:`run_masked`) are pure traced functions —
    ``jit``/``vmap`` them freely.  ``overrides`` swaps per-grid-point
    schedule arrays onto the scheme (``p_sched``, ``q_sched``) via
    :meth:`Scheme.with_overrides`; everything else about the scheme is
    static and shared by every point in a vmapped grid.
    """

    def __init__(self, x_dev: np.ndarray, y_dev: np.ndarray,
                 x_test: np.ndarray, y_test: np.ndarray, exp: Experiment):
        m, b, dim = x_dev.shape
        self.exp = exp
        self.m = m
        n_classes = int(np.max(y_dev)) + 1
        params = init_linear(dim, n_classes, jax.random.PRNGKey(exp.seed))
        flat0, self.unravel = jax.flatten_util.ravel_pytree(params)
        self.d = flat0.shape[0]
        self.params0 = params
        self.scheme = get_scheme(exp.cfg, self.d, m)
        self.localwork = get_local(exp.cfg, exp.local_lr)
        # static gate: cfg.scheduler == "none" resolves to None and no
        # scheduling op enters the trace (docs/DESIGN.md §12)
        self.scheduler = scheduling.get_scheduler(exp.cfg)
        if not self.localwork.identity and exp.local_steps > 1:
            raise ValueError(
                "local_steps > 1 (the legacy FedAvg path) conflicts with "
                f"the configured local algorithm {exp.cfg.local!r} at "
                f"local_epochs={exp.cfg.local_epochs}; use cfg.local_epochs")
        self._grad_fn = flat_grad_fn(self.unravel)
        self.opt = Optimizer(name=exp.optimizer, lr=exp.lr)
        self.xd, self.yd = jnp.asarray(x_dev), jnp.asarray(y_dev)
        self.xt, self.yt = jnp.asarray(x_test), jnp.asarray(y_test)
        self.ctx = MACContext(
            m=m, fading=exp.cfg.fading, csi=self.scheme.csi,
            use_kernel=exp.use_kernel or exp.cfg.use_kernel)

    # ------------------------------------------------------------- pieces
    def _carry0(self):
        carry = (self.params0, self.opt.init(self.params0),
                 jnp.zeros((self.m, self.d), jnp.float32),
                 jnp.zeros((self.m, self.d), jnp.float32))
        if self.localwork.has_dual:
            carry = carry + (self.localwork.init_dual(self.m, self.d),)
        if self._sched_state:
            carry = carry + (self.scheduler.init_state(self.m),)
        if self.exp.guard is not None:
            carry = carry + (guards.init_guard_state(),)
        return carry

    @property
    def _sched_state(self) -> bool:
        """Whether a scheduler state vector rides the scan carry (after
        the duals, before the guard state)."""
        return self.scheduler is not None and self.scheduler.has_state

    def _round(self, sch: Scheme, lw: LocalWork, carry, t, key, mask):
        exp = self.exp
        params, opt_state, deltas, momenta = carry[:4]
        duals = carry[4] if lw.has_dual else None
        sstate = (carry[4 + int(lw.has_dual)] if self._sched_state
                  else None)
        gstate = carry[-1] if exp.guard is not None else None
        old_extras = ((deltas, momenta) + ((duals,) if lw.has_dual else ())
                      + ((sstate,) if self._sched_state else ()))
        if lw.identity:
            # the pre-axis jaxpr, byte-for-byte — pins the goldens
            grads, momenta = device_grads(
                params, self.unravel, self.xd, self.yd, momenta,
                local_steps=exp.local_steps, local_lr=exp.local_lr,
                momentum_correction=exp.momentum_correction)
        else:
            grads, momenta, new_duals = local_device_grads(
                lw, self._grad_fn, params, self.xd, self.yd, momenta,
                duals, momentum_correction=exp.momentum_correction)
            if lw.has_dual:
                # padded phantom devices do not exist: their dual must not
                # evolve (same keep-rule round_masked applies to deltas)
                duals = (new_duals if mask is None else
                         jnp.where((mask > 0)[:, None], new_duals, duals))
        if self.scheduler is not None:
            # the scheduler needs the round's received-power factors
            # (post-geometry, post-fading) to rank, so the channel draw is
            # evaluated here — the identical expression round_masked would
            # have built (same salt, same mask) — and injected alongside
            # the transmit set; round_masked folds ``sched`` into the
            # active set so unscheduled devices bank via silent_state
            rmask = (mask if mask is not None
                     else jnp.ones((self.m,), jnp.float32))
            rmask_b = rmask > 0
            draw = sch.channel_draw(jax.random.fold_in(key, 2), t, self.m,
                                    mask=rmask_b)
            sched, new_sstate = scheduling.schedule(
                self.scheduler,
                jax.random.fold_in(key, scheduling.SALT_SCHED), t,
                draw.p_factor, sch.n_subbands, state=sstate, mask=rmask_b)
            if self._sched_state:
                # phantom (masked-out) devices' carried scheduler state
                # must not evolve — the deltas keep-rule
                sstate = (new_sstate if mask is None else
                          jnp.where(rmask_b, new_sstate, sstate))
            ghat, deltas, met = round_masked(sch, grads, deltas, t, key,
                                             rmask, self.ctx, draw=draw,
                                             sched=sched)
        elif mask is None and not sch.robust_on:
            ghat, deltas, met = round_simulated(sch, grads, deltas, t, key,
                                                self.ctx)
        else:
            # the fault-injection path lives in round_masked; an all-ones
            # mask is pinned bitwise-equal to round_simulated
            rmask = (mask if mask is not None
                     else jnp.ones((self.m,), jnp.float32))
            ghat, deltas, met = round_masked(sch, grads, deltas, t, key,
                                             rmask, self.ctx)
        extras = ((deltas, momenta) + ((duals,) if lw.has_dual else ())
                  + ((sstate,) if self._sched_state else ()))
        if exp.guard is None:
            params, opt_state = self.opt.apply(params, self.unravel(ghat),
                                               opt_state)
            out = {"acc": accuracy(params, self.xt, self.yt),
                   "loss": ce_loss(params, self.xt, self.yt),
                   "metrics": met}
            return (params, opt_state) + extras, out
        (params, opt_state, extras, gstate, loss,
         gmet) = guards.guarded_step(
            exp.guard, gstate, self.opt, params, opt_state, ghat,
            self.unravel, extras=extras, old_extras=old_extras,
            loss_fn=lambda p: ce_loss(p, self.xt, self.yt))
        out = {"acc": accuracy(params, self.xt, self.yt), "loss": loss,
               "metrics": {**met, **gmet}}
        return (params, opt_state) + tuple(extras) + (gstate,), out

    def _scan(self, overrides, keys, mask):
        carry, outs = self.run_segment(overrides, keys, mask,
                                       self._carry0(), 0)
        outs["params"] = carry[0]
        return outs

    # ------------------------------------------------------- traced entry
    def run_segment(self, overrides: Dict[str, jnp.ndarray],
                    keys: jnp.ndarray, mask, carry, t0):
        """Scan rounds ``t0 .. t0 + len(keys)`` from an explicit carry.

        The checkpoint/resume building block: a full run is the composition
        of its segments (the scan body is a pure function of ``(carry,
        (t, key))``), so splitting a run at any boundary and resuming from
        the saved carry reproduces the uninterrupted run bitwise.  Returns
        ``(carry, outs)``.

        ``overrides`` splits between the scheme (schedule arrays, channel /
        robustness scalars) and the local-work knobs
        (``LOCAL_OVERRIDE_ATTRS``) — each lands on its own carrier via the
        matching ``with_overrides``.
        """
        lw_ov = {k: v for k, v in overrides.items()
                 if k in LOCAL_OVERRIDE_ATTRS}
        sch_ov = {k: v for k, v in overrides.items()
                  if k not in LOCAL_OVERRIDE_ATTRS}
        sch = (self.scheme.with_overrides(**sch_ov) if sch_ov
               else self.scheme)
        lw = (self.localwork.with_overrides(**lw_ov) if lw_ov
              else self.localwork)

        def body(carry, inp):
            t, key = inp
            return self._round(sch, lw, carry, t, key, mask)

        ts = t0 + jnp.arange(keys.shape[0])
        return jax.lax.scan(body, carry, (ts, keys))

    def run(self, overrides: Dict[str, jnp.ndarray], keys: jnp.ndarray):
        """One full run. Returns {"acc": (steps,), "loss": (steps,),
        "metrics": {...: (steps,)}, "params": pytree}."""
        return self._scan(overrides, keys, None)

    def run_masked(self, overrides: Dict[str, jnp.ndarray],
                   keys: jnp.ndarray, mask: jnp.ndarray):
        """Padded-M variant: mask (M_pad,) marks live devices."""
        return self._scan(overrides, keys, mask)


def _concat_outs(chunks: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Concatenate per-segment scan outputs along the round axis."""
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *chunks)


def _restore_carry(ref_carry, loaded):
    """Rebuild a checkpointed carry against the engine's reference pytree
    (npz round-trips degrade NamedTuples — GuardState, BankedState — to
    plain tuples; the reference structure restores the classes)."""
    return jax.tree.unflatten(jax.tree.structure(ref_carry),
                              jax.tree.leaves(loaded))


def run_checkpointed(ce, overrides, keys, *, checkpoint_dir: str,
                     checkpoint_every: int, mask=None, resume: bool = False,
                     stop_after_step=None):
    """Drive a compiled runner in checkpointed segments.

    ``ce`` is any runner satisfying the segment contract: ``carry0()``
    (or legacy ``_carry0``) builds the initial scan carry, and
    ``run_segment(overrides, keys, mask, carry, t0)`` scans rounds
    ``t0 .. t0+len(keys)`` from an explicit carry, returning ``(carry,
    outs)``.  :class:`CompiledExperiment`,
    :class:`repro.population.CompiledPopulation` and
    :class:`repro.train.fedllm.CompiledFedLLM` all implement it, so one
    checkpoint driver serves the MNIST engines and the streamed-LLM loop
    alike.  Every ``checkpoint_every`` rounds the scan carry and the
    accumulated outputs are snapshotted via ``train/checkpoint.py``
    (atomic single-file replace); with ``resume=True`` the run continues
    from the latest snapshot.  Because a scan splits into segments as
    pure-function composition, the resumed run is *bitwise-equal* to the
    uninterrupted one (pinned by tests/test_robust.py and
    tests/test_fedllm.py).

    ``stop_after_step`` simulates an interruption: the driver returns
    ``None`` after the first segment boundary at or past it (the snapshot
    is on disk; rerun with ``resume=True`` to finish).  Returns the outs
    dict (with final ``params``) when the run completes.
    """
    steps = keys.shape[0]
    every = max(int(checkpoint_every), 1)
    path = os.path.join(checkpoint_dir, "engine_ckpt.npz")
    from repro.train.checkpoint import load_checkpoint, save_checkpoint

    carry = (ce.carry0() if hasattr(ce, "carry0") else ce._carry0())
    t0 = 0
    chunks: List[Dict[str, Any]] = []
    if resume and os.path.exists(path):
        loaded, t0 = load_checkpoint(path)
        carry = _restore_carry(carry, loaded["carry"])
        if t0 > 0:
            chunks = [jax.tree.map(np.asarray, loaded["outs"])]

    seg_fn = jax.jit(lambda ov, k, c, t: ce.run_segment(ov, k, mask, c, t))
    while t0 < steps:
        n = min(every, steps - t0)
        carry, outs = seg_fn(overrides, keys[t0:t0 + n], carry,
                             jnp.int32(t0))
        chunks.append(jax.tree.map(np.asarray, outs))
        t0 += n
        save_checkpoint(path, {"carry": carry,
                               "outs": _concat_outs(chunks)}, step=t0)
        if (stop_after_step is not None and t0 >= stop_after_step
                and t0 < steps):
            return None
    outs = _concat_outs(chunks)
    outs["params"] = jax.tree.map(np.asarray, carry[0])
    return outs


def _subsample(outs, exp: Experiment) -> EngineRun:
    idx = eval_indices(exp.steps, exp.eval_every)
    accs = np.asarray(outs["acc"])
    losses = np.asarray(outs["loss"])
    mets = {k: np.asarray(v) for k, v in outs["metrics"].items()}
    return EngineRun(
        accs=[float(accs[i]) for i in idx],
        losses=[float(losses[i]) for i in idx],
        metrics=[{k: float(v[i]) for k, v in mets.items()} for i in idx],
        eval_steps=idx, all_accs=accs, all_losses=losses,
        params=outs.get("params"))


def run_compiled(x_dev: np.ndarray, y_dev: np.ndarray, x_test: np.ndarray,
                 y_test: np.ndarray, cfg: OTAConfig, steps: int,
                 lr: float = 1e-3, eval_every: int = 10, seed: int = 0,
                 optimizer: str = "adam", local_steps: int = 1,
                 local_lr: float = 0.1, momentum_correction: float = 0.0,
                 use_kernel: bool = False,
                 guard: Optional[guards.GuardConfig] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, resume: bool = False,
                 stop_after_step=None) -> Optional[EngineRun]:
    """Compiled replacement for ``run_federated``: same model, same
    schedule — one jitted scan instead of a Python loop.  At ``seed=0``
    the per-round key stream is ``run_federated``'s exactly
    (``PRNGKey(1000 + t)``), so ``accs`` / ``losses`` / ``metrics`` match
    ``FederatedRun``'s lists entry for entry (pinned by
    tests/test_experiments.py).  Nonzero ``seed`` shifts the stream to a
    disjoint key range for independent replicas — a knob the reference
    loop does not have (its ``seed`` argument never reaches the round
    keys), so cross-implementation parity holds at seed 0 only.

    ``guard`` enables the in-scan round guardrails
    (:class:`repro.robust.guards.GuardConfig`); ``checkpoint_dir`` +
    ``checkpoint_every`` switch to the segmented checkpoint/resume driver
    (:func:`run_checkpointed`) — with ``resume=True`` an interrupted run
    continues from its snapshot, bitwise-equal to the uninterrupted run.
    Returns ``None`` when ``stop_after_step`` interrupts the run."""
    exp = Experiment(cfg=cfg, steps=steps, lr=lr, eval_every=eval_every,
                     optimizer=optimizer, local_steps=local_steps,
                     local_lr=local_lr, momentum_correction=momentum_correction,
                     seed=seed, use_kernel=use_kernel, guard=guard)
    ce = CompiledExperiment(x_dev, y_dev, x_test, y_test, exp)
    keys = round_keys(steps, seed)
    if checkpoint_dir is not None and checkpoint_every > 0:
        outs = run_checkpointed(ce, {}, keys, checkpoint_dir=checkpoint_dir,
                                checkpoint_every=checkpoint_every,
                                resume=resume,
                                stop_after_step=stop_after_step)
        if outs is None:
            return None
    else:
        outs = jax.jit(ce.run)({}, keys)
        outs = jax.tree.map(np.asarray, outs)
    return _subsample(outs, exp)
