"""Compiled experiment engine: a federated run as ONE jitted ``lax.scan``.

``train/paper_repro.run_federated`` is the reference implementation — a
Python loop dispatching one jitted round at a time, with host evals in
between.  This module compiles the *entire run* instead: the scan carry is
``(params, opt_state, deltas, momenta)``, each scan step performs the full
round (per-device gradients -> scheme encode -> MAC -> PS decode -> ADAM)
with the paper's per-round key stream, and test accuracy/loss are computed
inside the scan, so ``steps`` rounds cost one XLA dispatch and zero host
round-trips.  ``repro.experiments.sweep`` vmaps whole sweep grids over the
scan (see docs/DESIGN.md §6 for the traced/static split).

The round body is built from the same pieces as the reference loop
(``device_grads``, ``round_simulated``, ``Optimizer.apply``), which is what
the bitwise parity test in ``tests/test_experiments.py`` pins.

Device-count sweeps use :func:`round_masked`: M is a *shape*, so a vmapped
M-axis pads every grid point to ``M_pad`` devices and silences the padding
with a traced participation mask (docs/DESIGN.md §6 explains why padding,
not reshaping, is the only way to put M on a vmap axis).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core import channel
from repro.core import schemes as schemes_mod
from repro.core.schemes import MACContext, Scheme, get_scheme, round_simulated
from repro.optim.optim import Optimizer
from repro.train.paper_repro import (
    accuracy, ce_loss, device_grads, init_linear,
)

#: base of the per-round key stream; round t of seed 0 uses PRNGKey(1000 + t),
#: matching run_federated exactly (seed k shifts the stream by k * steps so
#: seed sweeps draw disjoint keys)
KEY_STREAM_BASE = 1000


def round_keys(steps: int, seed: int = 0) -> jnp.ndarray:
    """(steps, ...) stacked per-round PRNG keys for one run."""
    seeds = KEY_STREAM_BASE + seed * steps + jnp.arange(steps)
    return jax.vmap(jax.random.PRNGKey)(seeds)


def eval_indices(steps: int, eval_every: int) -> np.ndarray:
    """The rounds run_federated evaluates after (t % every == 0 or last)."""
    return np.asarray([t for t in range(steps)
                       if t % eval_every == 0 or t == steps - 1], np.int64)


@dataclass(frozen=True)
class Experiment:
    """Static description of one federated training configuration."""
    cfg: OTAConfig
    steps: int
    lr: float = 1e-3
    eval_every: int = 10
    optimizer: str = "adam"
    local_steps: int = 1
    local_lr: float = 0.1
    momentum_correction: float = 0.0
    seed: int = 0
    use_kernel: bool = False     # Pallas projection/AMP inside the scan


@dataclass
class EngineRun:
    """Result of one compiled run — mirrors FederatedRun at eval points."""
    accs: List[float]
    losses: List[float]
    metrics: List[Dict[str, float]]
    eval_steps: np.ndarray
    all_accs: np.ndarray         # (steps,) — every round, free inside scan
    all_losses: np.ndarray
    params: Any = None           # final model parameters (pytree)


# ---------------------------------------------------------------------------
# masked round (padded device-count sweeps)
# ---------------------------------------------------------------------------


def round_masked(scheme: Scheme, grads: jnp.ndarray, deltas: jnp.ndarray,
                 step, key: jnp.ndarray, mask: jnp.ndarray, ctx: MACContext,
                 *, dev_keys=None, draw=None, mac=None):
    """:func:`~repro.core.schemes.round_simulated` with a traced device mask.

    ``mask`` (M_pad,) marks which padded devices exist at this grid point:
    masked-out devices transmit nothing (their frames — including the analog
    power/mean slots — are zeroed before the MAC sum), keep their error
    state untouched, and the PS decodes against the traced effective device
    count.  The RNG layout (key salts, ``split(key, M_pad)``) matches
    ``round_simulated`` at ``M = M_pad``, so an all-ones mask reproduces it
    exactly (masking multiplies frames by 1.0 and adds 0.0 to the sum).

    The keyword hooks re-seat the round on a sampled cohort
    (:mod:`repro.population`): ``dev_keys`` (M_pad, ...) replaces the
    in-place key split, ``draw`` replaces the channel realisation (the
    cohort view of a full-population draw), and ``mac`` — a callable
    ``(frames, key, sigma2) -> y`` — replaces the flat analog MAC sum
    (hierarchical edge-site aggregation).  Defaults preserve the legacy
    path bitwise.
    """
    m_pad = grads.shape[0]
    mask_b = mask > 0
    # the max guard only engages when *every* device is masked out (an
    # empty cohort round); any populated mask is untouched bitwise
    m_eff = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    ctx = dataclasses.replace(ctx, m=m_eff)
    if dev_keys is None:
        dev_keys = jax.random.split(jax.random.fold_in(key, 1), m_pad)
    if draw is None:
        # device-coupled draws (the blind PS combiner) must not see the
        # padded phantom devices' channels; an all-ones mask multiplies
        # rows by 1.0, so the unmasked equivalence below still holds bitwise
        draw = scheme.channel_draw(jax.random.fold_in(key, 2), step, m_pad,
                                   mask=mask_b)
    active = draw.active
    frames, new_deltas, metrics = jax.vmap(
        lambda g, dl, kk, pf: scheme.encode(g, dl, step, kk,
                                            ctx.with_p_factor(pf)))(
            grads, deltas, dev_keys, draw.p_factor)
    if scheme.analog:
        new_deltas = jnp.where(active[:, None], new_deltas,
                               scheme.silent_state(grads, deltas, new_deltas))
        active = active & mask_b
        frames = schemes_mod.apply_channel_gain(
            frames, draw._replace(active=active))
        mac_key = jax.random.fold_in(key, 0)
        sigma2 = schemes_mod.round_sigma2(scheme, draw)
        y = (channel.mac_sum(frames, mac_key, sigma2) if mac is None
             else mac(frames, mac_key, sigma2))
    else:
        active = active & mask_b
        frames = frames * mask_b[:, None]
        y = jnp.sum(frames, axis=0)
    # padded devices do not exist: their error state must not evolve
    new_deltas = jnp.where(mask_b[:, None], new_deltas, deltas)
    ghat = scheme.decode(y, step, ctx)
    w = mask.astype(jnp.float32)
    metrics = {k: jnp.sum(v * w) / m_eff for k, v in metrics.items()}
    metrics["active_frac"] = jnp.sum(active.astype(jnp.float32)) / m_eff
    return ghat, new_deltas, metrics


# ---------------------------------------------------------------------------
# the compiled runner
# ---------------------------------------------------------------------------


class CompiledExperiment:
    """Compile-once runner for one static configuration.

    :meth:`run` (and :meth:`run_masked`) are pure traced functions —
    ``jit``/``vmap`` them freely.  ``overrides`` swaps per-grid-point
    schedule arrays onto the scheme (``p_sched``, ``q_sched``) via
    :meth:`Scheme.with_overrides`; everything else about the scheme is
    static and shared by every point in a vmapped grid.
    """

    def __init__(self, x_dev: np.ndarray, y_dev: np.ndarray,
                 x_test: np.ndarray, y_test: np.ndarray, exp: Experiment):
        m, b, dim = x_dev.shape
        self.exp = exp
        self.m = m
        n_classes = int(np.max(y_dev)) + 1
        params = init_linear(dim, n_classes, jax.random.PRNGKey(exp.seed))
        flat0, self.unravel = jax.flatten_util.ravel_pytree(params)
        self.d = flat0.shape[0]
        self.params0 = params
        self.scheme = get_scheme(exp.cfg, self.d, m)
        self.opt = Optimizer(name=exp.optimizer, lr=exp.lr)
        self.xd, self.yd = jnp.asarray(x_dev), jnp.asarray(y_dev)
        self.xt, self.yt = jnp.asarray(x_test), jnp.asarray(y_test)
        self.ctx = MACContext(
            m=m, fading=exp.cfg.fading, csi=self.scheme.csi,
            use_kernel=exp.use_kernel or exp.cfg.use_kernel)

    # ------------------------------------------------------------- pieces
    def _carry0(self):
        return (self.params0, self.opt.init(self.params0),
                jnp.zeros((self.m, self.d), jnp.float32),
                jnp.zeros((self.m, self.d), jnp.float32))

    def _round(self, sch: Scheme, carry, t, key, mask):
        params, opt_state, deltas, momenta = carry
        exp = self.exp
        grads, momenta = device_grads(
            params, self.unravel, self.xd, self.yd, momenta,
            local_steps=exp.local_steps, local_lr=exp.local_lr,
            momentum_correction=exp.momentum_correction)
        if mask is None:
            ghat, deltas, met = round_simulated(sch, grads, deltas, t, key,
                                                self.ctx)
        else:
            ghat, deltas, met = round_masked(sch, grads, deltas, t, key,
                                             mask, self.ctx)
        params, opt_state = self.opt.apply(params, self.unravel(ghat),
                                           opt_state)
        out = {"acc": accuracy(params, self.xt, self.yt),
               "loss": ce_loss(params, self.xt, self.yt),
               "metrics": met}
        return (params, opt_state, deltas, momenta), out

    def _scan(self, overrides, keys, mask):
        sch = (self.scheme.with_overrides(**overrides) if overrides
               else self.scheme)
        steps = self.exp.steps

        def body(carry, inp):
            t, key = inp
            return self._round(sch, carry, t, key, mask)

        carry, outs = jax.lax.scan(body, self._carry0(),
                                   (jnp.arange(steps), keys))
        outs["params"] = carry[0]
        return outs

    # ------------------------------------------------------- traced entry
    def run(self, overrides: Dict[str, jnp.ndarray], keys: jnp.ndarray):
        """One full run. Returns {"acc": (steps,), "loss": (steps,),
        "metrics": {...: (steps,)}, "params": pytree}."""
        return self._scan(overrides, keys, None)

    def run_masked(self, overrides: Dict[str, jnp.ndarray],
                   keys: jnp.ndarray, mask: jnp.ndarray):
        """Padded-M variant: mask (M_pad,) marks live devices."""
        return self._scan(overrides, keys, mask)


def _subsample(outs, exp: Experiment) -> EngineRun:
    idx = eval_indices(exp.steps, exp.eval_every)
    accs = np.asarray(outs["acc"])
    losses = np.asarray(outs["loss"])
    mets = {k: np.asarray(v) for k, v in outs["metrics"].items()}
    return EngineRun(
        accs=[float(accs[i]) for i in idx],
        losses=[float(losses[i]) for i in idx],
        metrics=[{k: float(v[i]) for k, v in mets.items()} for i in idx],
        eval_steps=idx, all_accs=accs, all_losses=losses,
        params=outs.get("params"))


def run_compiled(x_dev: np.ndarray, y_dev: np.ndarray, x_test: np.ndarray,
                 y_test: np.ndarray, cfg: OTAConfig, steps: int,
                 lr: float = 1e-3, eval_every: int = 10, seed: int = 0,
                 optimizer: str = "adam", local_steps: int = 1,
                 local_lr: float = 0.1, momentum_correction: float = 0.0,
                 use_kernel: bool = False) -> EngineRun:
    """Compiled replacement for ``run_federated``: same model, same
    schedule — one jitted scan instead of a Python loop.  At ``seed=0``
    the per-round key stream is ``run_federated``'s exactly
    (``PRNGKey(1000 + t)``), so ``accs`` / ``losses`` / ``metrics`` match
    ``FederatedRun``'s lists entry for entry (pinned by
    tests/test_experiments.py).  Nonzero ``seed`` shifts the stream to a
    disjoint key range for independent replicas — a knob the reference
    loop does not have (its ``seed`` argument never reaches the round
    keys), so cross-implementation parity holds at seed 0 only."""
    exp = Experiment(cfg=cfg, steps=steps, lr=lr, eval_every=eval_every,
                     optimizer=optimizer, local_steps=local_steps,
                     local_lr=local_lr, momentum_correction=momentum_correction,
                     seed=seed, use_kernel=use_kernel)
    ce = CompiledExperiment(x_dev, y_dev, x_test, y_test, exp)
    outs = jax.jit(ce.run)({}, round_keys(steps, seed))
    outs = jax.tree.map(np.asarray, outs)
    return _subsample(outs, exp)
