"""repro.experiments: the compiled experiment engine behind the paper figures.

One federated run — device gradients, scheme encode, MAC superposition, PS
decode, ADAM update — is a single ``jax.lax.scan`` over rounds inside one
``jit`` (:mod:`repro.experiments.engine`); sweep grids vmap schedule-shaped
axes on top of the scan so a paper figure executes as one XLA program
(:mod:`repro.experiments.sweep`).  See ``docs/EXPERIMENTS.md`` for the
guide and ``docs/DESIGN.md`` §6 for what is traced vs static.
"""
from repro.experiments.engine import (  # noqa: F401
    CompiledExperiment, EngineRun, Experiment, eval_indices, round_keys,
    round_masked, run_compiled,
)
from repro.experiments.sweep import (  # noqa: F401
    LOCAL_VMAP_AXES, POP_VMAP_AXES, ROBUST_VMAP_AXES, SCALAR_VMAP_AXES,
    VMAP_AXES, SweepResult, run_population_sweep, run_sweep,
)
