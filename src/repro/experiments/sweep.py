"""Sweep grids over the compiled engine: vmap what traces, compile the rest.

A sweep axis is either *schedule-shaped* — its value enters the round as an
array of per-step scalars, so a whole grid of values rides one trace as a
vmapped batch — or *shape-defining* — it changes array shapes or the
compiled structure (projector size, scheme class), so each value needs its
own XLA program (still a single scan-over-rounds each, never a Python
per-round loop).

vmapped axes (``VMAP_AXES``):

``p_avg``          average power P-bar  -> the (T,) power schedule array
``power_schedule`` schedule shape       -> the same (T,) array
``seed``           round-key stream     -> the (T, key) array
``m_active``       device count         -> a traced participation mask over
                                           M_pad padded devices
                                           (:func:`engine.round_masked`)

plus the channel-model scalars (``SCALAR_VMAP_AXES``): ``csi_err_var``,
``fading_threshold``, ``fading_rho``, and the geometry/scheduling trio
``cell_radius`` / ``path_loss_exp`` / ``n_subbands`` enter the round as one
traced scalar each (a multiply or compare inside the scheme's channel draw
or the subband cutoff), so a whole CSI-error / truncation / correlation /
cell-size / subband-budget grid rides one vmapped program.
The fault/robustness rates (``ROBUST_VMAP_AXES``) vmap the same way —
sweeping one auto-promotes the config to ``robust=True`` so the (static)
fault path is compiled in for the whole grid.

Everything else (``scheme``, ``s_frac``, ``k_frac``, ``projection``,
``amp_iters``, ``sigma2``, ...) is an ``OTAConfig`` field swept statically:
the grid is grouped by static combo, one compile per combo, and the
vmapped sub-grid runs inside it.  For the digital schemes the per-step bit
budget ``q_t`` is host-precomputed per grid point and vmapped alongside the
power schedule (the static ``q_max`` bound is shared across the grid —
``top_k``'s q-th value is invariant to computing extra entries, so results
are bitwise identical to per-point bounds).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core import power
from repro.experiments.engine import (
    CompiledExperiment, Experiment, eval_indices, round_keys,
)
from repro.local.work import LOCAL_OVERRIDE_ATTRS

#: axes realised as vmapped per-point arrays on one trace
VMAP_AXES = ("p_avg", "power_schedule", "seed", "m_active")

#: OTAConfig fields that enter the round as a single traced scalar (a
#: compare or multiply inside the channel draw) — vmapped like the schedule
#: axes, but realised as a (G,) stack of per-point values swapped onto the
#: scheme via ``with_overrides`` (the attribute of the same name, set by
#: ``Scheme.__init__``).  docs/DESIGN.md §8 records why these are
#: data-like while ``fading_process`` / ``fading_window`` / ``ps_antennas``
#: are structure-defining and stay static.  The geometry/scheduling trio
#: (``cell_radius``, ``path_loss_exp``, ``n_subbands`` — DESIGN.md §12)
#: follows the same rule: each is one multiply or compare on a fixed
#: program, while ``geometry`` / ``scheduler`` select program structure
#: and stay static axes.
SCALAR_VMAP_AXES = ("csi_err_var", "fading_threshold", "fading_rho",
                    "cell_radius", "path_loss_exp", "n_subbands")

#: population knobs that enter the round as one traced scalar each
#: (compares/multiplies inside the cohort mask and the site MAC), swapped
#: onto the CompiledPopulation runner via its with_overrides — vmapped like
#: the channel scalars.  ``k_cohort`` / ``n_sites`` / ``capacity`` are
#: shape-defining and stay static (docs/DESIGN.md §9).
POP_VMAP_AXES = ("avail_rate", "straggler_deadline", "k_active",
                 "site_noise_scale", "backhaul_sigma2")

#: fault/robustness *rates* — traced scalars on the scheme (compares and
#: multiplies inside the fault draw / aggregator / power clip), so a whole
#: Byzantine-fraction or fault-rate grid rides one vmapped program.
#: Sweeping any of them auto-promotes the base config to ``robust=True``
#: (the static gate that compiles the fault path in; with all rates zero
#: that path is bitwise-neutral — pinned by tests/test_robust.py).  The
#: fault/aggregator *kinds* (``byz_attack``, ``fault_kind``,
#: ``aggregator``, ``clip_power``) select program structure and stay
#: static axes (docs/DESIGN.md §10).
ROBUST_VMAP_AXES = ("byzantine_frac", "fault_rate", "erasure_prob",
                    "byz_scale", "trim_frac", "norm_cap", "power_cap")

#: local-compute knobs (repro.local) — traced scalars on the LocalWork
#: carrier, swapped per grid point via ``LocalWork.with_overrides``.  The
#: epoch count is traced (a ``e < local_epochs`` cutoff inside a scan of
#: static length ``max_epochs``), so a whole (E, mu, alpha) grid rides one
#: vmapped program; sweeping ``local_epochs`` bumps the static
#: ``max_epochs`` bound to the grid maximum before tracing (the ``q_max``
#: pattern — discarded epochs leave the carry untouched bitwise).  The
#: algorithm *kind* (``local``) selects program structure and stays a
#: static axis (docs/DESIGN.md §11).
LOCAL_VMAP_AXES = LOCAL_OVERRIDE_ATTRS


@dataclass
class SweepResult:
    """One record per grid point, ``accs``/``final_acc`` at eval steps —
    the same reading ``benchmarks.common.run_series`` extracts from a
    looped ``FederatedRun``."""
    records: List[Dict[str, Any]]
    eval_steps: np.ndarray
    steps: int
    wall_s: float

    def record(self, **axis_values) -> Dict[str, Any]:
        """The unique record matching the given axis values."""
        hits = [r for r in self.records
                if all(r[k] == v for k, v in axis_values.items())]
        if len(hits) != 1:
            raise KeyError(f"{axis_values} matched {len(hits)} records")
        return hits[0]


def _validate_axes(axes: Dict[str, Sequence], base: OTAConfig) -> None:
    cfg_fields = {f.name for f in dataclasses.fields(OTAConfig)}
    vmapped = VMAP_AXES + SCALAR_VMAP_AXES + ROBUST_VMAP_AXES \
        + LOCAL_VMAP_AXES
    for name, values in axes.items():
        if name not in vmapped and name not in cfg_fields:
            raise KeyError(
                f"unknown sweep axis {name!r}: vmapped axes are "
                f"{vmapped}, static axes are OTAConfig fields")
        if not len(list(values)):
            raise ValueError(f"sweep axis {name!r} is empty")


def run_sweep(dev_data, test_data, base: OTAConfig,
              axes: Dict[str, Sequence], *, steps: int, lr: float = 1e-3,
              eval_every: int = 10, optimizer: str = "adam", seed: int = 0,
              local_lr: float = 0.1, use_kernel: bool = False) -> SweepResult:
    """Run the cartesian grid of ``axes`` over ``base``.

    dev_data = (x_dev (M, B, dim), y_dev), test_data = (x_test, y_test).
    For an ``m_active`` axis the device tensors are the M_pad padding; every
    value must be <= M_pad.
    """
    (xd, yd), (xt, yt) = dev_data, test_data
    axes = {k: list(v) for k, v in axes.items()}
    _validate_axes(axes, base)
    if any(k in ROBUST_VMAP_AXES for k in axes):
        # the swept rates are traced, but the fault path itself is a
        # static gate — compile it in for the whole grid
        base = dataclasses.replace(base, robust=True)
    m_pad = xd.shape[0]
    masked = "m_active" in axes
    if masked and max(axes["m_active"]) > m_pad:
        raise ValueError(f"m_active values must be <= M_pad = {m_pad}")

    vmapped = VMAP_AXES + SCALAR_VMAP_AXES + ROBUST_VMAP_AXES \
        + LOCAL_VMAP_AXES
    static_names = [k for k in axes if k not in vmapped]
    vmap_names = [k for k in axes if k in vmapped]
    records: List[Dict[str, Any]] = []
    t0 = time.time()

    for static_vals in itertools.product(*[axes[k] for k in static_names]):
        static_d = dict(zip(static_names, static_vals))
        cfg = dataclasses.replace(base, **static_d)
        exp = Experiment(cfg=cfg, steps=steps, lr=lr, eval_every=eval_every,
                         optimizer=optimizer, seed=seed, local_lr=local_lr,
                         use_kernel=use_kernel)
        ce = CompiledExperiment(xd, yd, xt, yt, exp)
        digital = hasattr(ce.scheme, "q_sched")
        if "local_epochs" in axes:
            # the static scan bound must cover the whole grid (the q_max
            # pattern): points at E < max run the extra epochs as bitwise
            # no-ops behind the traced cutoff
            ce.localwork.max_epochs = max(int(max(axes["local_epochs"])), 1)

        grid = ([dict(zip(vmap_names, vals)) for vals in itertools.product(
            *[axes[k] for k in vmap_names])] if vmap_names else [{}])

        # --- per-point schedule arrays (host precompute) -----------------
        scalar_names = [k for k in vmap_names
                        if k in SCALAR_VMAP_AXES or k in ROBUST_VMAP_AXES
                        or k in LOCAL_VMAP_AXES]
        p_rows, q_rows, key_rows, mask_rows = [], [], [], []
        scalar_rows: Dict[str, List[float]] = {k: [] for k in scalar_names}
        for point in grid:
            p_avg = point.get("p_avg", cfg.p_avg)
            sched = point.get("power_schedule", cfg.power_schedule)
            m_eff = point.get("m_active", m_pad)
            p_np = power.schedule_array(cfg.total_steps, p_avg, sched)
            p_rows.append(np.asarray(p_np, np.float32))
            if digital:
                # the scheme's own budget/cap rule, with this point's
                # effective device count
                q_rows.append(ce.scheme.build_q_schedule(m_eff, p_np))
            key_rows.append(round_keys(steps, point.get("seed", seed)))
            if masked:
                mask_rows.append(
                    (np.arange(m_pad) < m_eff).astype(np.float32))
            for k in scalar_names:
                scalar_rows[k].append(point[k])

        overrides = {"p_sched": jnp.asarray(np.stack(p_rows))}
        for k in scalar_names:
            overrides[k] = jnp.asarray(scalar_rows[k], jnp.float32)
        if digital:
            q_grid = np.stack(q_rows)
            ce.scheme.q_max = int(max(int(q_grid.max()), 1))
            overrides["q_sched"] = jnp.asarray(q_grid, jnp.int32)
        keys = jnp.stack(key_rows)

        # --- one XLA program for the whole vmapped sub-grid --------------
        ov_axes = {k: 0 for k in overrides}
        if masked:
            masks = jnp.asarray(np.stack(mask_rows))
            outs = jax.jit(jax.vmap(ce.run_masked,
                                    in_axes=(ov_axes, 0, 0)))(
                overrides, keys, masks)
        else:
            outs = jax.jit(jax.vmap(ce.run, in_axes=(ov_axes, 0)))(
                overrides, keys)
        outs.pop("params")
        outs = jax.tree.map(np.asarray, outs)

        idx = eval_indices(steps, eval_every)
        for g, point in enumerate(grid):
            accs = outs["acc"][g]
            rec: Dict[str, Any] = {**static_d, **point}
            rec["accs"] = [float(accs[i]) for i in idx]
            rec["losses"] = [float(outs["loss"][g][i]) for i in idx]
            rec["metrics"] = [
                {k: float(v[g][i]) for k, v in outs["metrics"].items()}
                for i in idx]
            rec["final_acc"] = rec["accs"][-1]
            records.append(rec)

    wall = time.time() - t0
    us = wall / max(len(records) * steps, 1) * 1e6
    for rec in records:
        rec["us_per_call"] = us
    return SweepResult(records=records, eval_steps=eval_indices(
        steps, eval_every), steps=steps, wall_s=wall)


def run_population_sweep(data, test_data, base: OTAConfig, base_pop,
                         axes: Dict[str, Sequence], *, steps: int,
                         lr: float = 1e-3, eval_every: int = 10,
                         optimizer: str = "adam", seed: int = 0,
                         local_lr: float = 0.1,
                         use_kernel: bool = False) -> SweepResult:
    """:func:`run_sweep` over the sampled-cohort population engine.

    ``data`` is a :class:`repro.population.PopulationData`; ``base_pop`` a
    :class:`repro.population.PopulationConfig`.  Vmapped axes are
    ``p_avg`` / ``power_schedule`` / ``seed``, the channel scalars
    (``SCALAR_VMAP_AXES``) and the population scalars (``POP_VMAP_AXES``);
    static axes are any OTAConfig *or* PopulationConfig field (grouped by
    combo, one compile each).  ``m_active`` is a padded-M dense-engine
    axis — its sampled-cohort analogue here is ``k_active`` (every value
    must be <= the static ``k_cohort``).
    """
    from repro.population.engine import (
        CompiledPopulation, PopulationExperiment,
    )
    from repro.population.state import PopulationConfig

    (xt, yt) = test_data
    axes = {k: list(v) for k, v in axes.items()}
    if any(k in ROBUST_VMAP_AXES for k in axes):
        base = dataclasses.replace(base, robust=True)
    cfg_fields = {f.name for f in dataclasses.fields(OTAConfig)}
    pop_fields = {f.name for f in dataclasses.fields(PopulationConfig)}
    vmapped = ("p_avg", "power_schedule", "seed") + SCALAR_VMAP_AXES \
        + POP_VMAP_AXES + ROBUST_VMAP_AXES + LOCAL_VMAP_AXES
    for name, values in axes.items():
        if name == "m_active":
            raise KeyError(
                "m_active is a dense-engine axis; the population engine "
                "sweeps the cohort via k_active")
        if name not in vmapped and name not in cfg_fields \
                and name not in pop_fields:
            raise KeyError(
                f"unknown sweep axis {name!r}: vmapped axes are {vmapped}, "
                "static axes are OTAConfig/PopulationConfig fields")
        if not len(values):
            raise ValueError(f"sweep axis {name!r} is empty")
    if "k_active" in axes and max(axes["k_active"]) > base_pop.k_cohort:
        raise ValueError(
            f"k_active values must be <= k_cohort = {base_pop.k_cohort}")

    static_names = [k for k in axes if k not in vmapped]
    vmap_names = [k for k in axes if k in vmapped]
    records: List[Dict[str, Any]] = []
    t0 = time.time()

    for static_vals in itertools.product(*[axes[k] for k in static_names]):
        static_d = dict(zip(static_names, static_vals))
        cfg = dataclasses.replace(
            base, **{k: v for k, v in static_d.items() if k in cfg_fields})
        pop = dataclasses.replace(
            base_pop,
            **{k: v for k, v in static_d.items() if k in pop_fields})
        exp = PopulationExperiment(cfg=cfg, pop=pop, steps=steps, lr=lr,
                                   eval_every=eval_every,
                                   optimizer=optimizer, seed=seed,
                                   local_lr=local_lr,
                                   use_kernel=use_kernel)
        cp = CompiledPopulation(data, xt, yt, exp)
        digital = hasattr(cp.scheme, "q_sched")
        if "local_epochs" in axes:
            # static scan bound covers the grid (see run_sweep)
            cp.localwork.max_epochs = max(int(max(axes["local_epochs"])), 1)

        grid = ([dict(zip(vmap_names, vals)) for vals in itertools.product(
            *[axes[k] for k in vmap_names])] if vmap_names else [{}])

        scalar_names = [k for k in vmap_names
                        if k in SCALAR_VMAP_AXES or k in POP_VMAP_AXES
                        or k in ROBUST_VMAP_AXES or k in LOCAL_VMAP_AXES]
        p_rows, q_rows, key_rows = [], [], []
        scalar_rows: Dict[str, List[float]] = {k: [] for k in scalar_names}
        for point in grid:
            p_np = power.schedule_array(
                cfg.total_steps, point.get("p_avg", cfg.p_avg),
                point.get("power_schedule", cfg.power_schedule))
            p_rows.append(np.asarray(p_np, np.float32))
            if digital:
                # the digital bit budget tracks the point's effective
                # cohort (the k_active analogue of m_active's q rule)
                q_rows.append(cp.scheme.build_q_schedule(
                    int(point.get("k_active", pop.k_cohort)), p_np))
            key_rows.append(round_keys(steps, point.get("seed", seed)))
            for k in scalar_names:
                scalar_rows[k].append(point[k])

        overrides = {"p_sched": jnp.asarray(np.stack(p_rows))}
        for k in scalar_names:
            overrides[k] = jnp.asarray(scalar_rows[k], jnp.float32)
        if digital:
            q_grid = np.stack(q_rows)
            cp.scheme.q_max = int(max(int(q_grid.max()), 1))
            overrides["q_sched"] = jnp.asarray(q_grid, jnp.int32)
        keys = jnp.stack(key_rows)

        ov_axes = {k: 0 for k in overrides}
        outs = jax.jit(jax.vmap(cp.run, in_axes=(ov_axes, 0)))(
            overrides, keys)
        outs.pop("params")
        outs = jax.tree.map(np.asarray, outs)

        idx = eval_indices(steps, eval_every)
        for g, point in enumerate(grid):
            rec: Dict[str, Any] = {**static_d, **point}
            rec["accs"] = [float(outs["acc"][g][i]) for i in idx]
            rec["losses"] = [float(outs["loss"][g][i]) for i in idx]
            rec["metrics"] = [
                {k: float(v[g][i]) for k, v in outs["metrics"].items()}
                for i in idx]
            rec["final_acc"] = rec["accs"][-1]
            records.append(rec)

    wall = time.time() - t0
    us = wall / max(len(records) * steps, 1) * 1e6
    for rec in records:
        rec["us_per_call"] = us
    return SweepResult(records=records, eval_steps=eval_indices(
        steps, eval_every), steps=steps, wall_s=wall)
