"""Pluggable aggregation schemes: one encode/decode contract for every MAC.

The paper contributes a *family* of gradient aggregation schemes over a
shared wireless multiple-access channel (ideal, A-DSGD, D-DSGD, SignSGD,
QSGD), and the follow-up work adds channel variants (Rayleigh fading with
truncated inversion).  This module makes the family extensible: each scheme
is a class implementing the :class:`Scheme` contract

    init_state(d)              -- per-device error accumulator Delta_m(0)
    encode(g, state, step, key, ctx)   -- device-side compression + frame
    decode(y, step, ctx)       -- PS-side reconstruction from the MAC output
    channel_dim(d)             -- channel uses consumed per round

registered under a name with :func:`register_scheme` and resolved from an
``OTAConfig`` via :func:`get_scheme`.  Schemes that support the fully-sharded
slice driver additionally implement ``encode_slice`` / ``decode_slice``
(see :mod:`repro.core.distributed`).

Three generic drivers run *any* registered scheme without per-scheme
branches (scheme behaviour is expressed through the hooks, never through
name dispatch):

  * :func:`round_simulated` -- M devices on one host; the MAC is a sum over
    the leading axis (paper-scale benchmarks).
  * :func:`round_sharded`   -- inside a shard_map; the MAC is ``lax.psum``
    over the manual mesh axes (the TPU ICI plays the superposing channel).
  * :func:`repro.core.distributed.sharded_round` -- fully-sharded slices;
    every device owns ``d_pad / n_shards`` entries, nothing d-sized is ever
    replicated.

Topology facts (device axes, shard axes, group structure, per-device fading
power factor, perf knobs) travel in an explicit :class:`MACContext` so the
same scheme object serves all three drivers.  The *channel* is its own
pluggable axis (:mod:`repro.core.fading`): per round the drivers ask the
scheme for a :class:`ChannelDraw` — received-power factor, transmit set,
frame gain, noise scale — so fading processes (static / iid / gauss_markov)
and CSI models (perfect / noisy estimate / none) compose with any analog
scheme; see ``ADSGDFadingScheme`` / ``ADSGDCSIErrScheme`` /
``ADSGDBlindScheme`` and docs/DESIGN.md §8.

Registering a new scheme takes ~10 lines::

    @register_scheme("a_dsgd_fading")
    class ADSGDFadingScheme(ADSGDScheme):
        def device_factors(self, key, m):
            h = channel.rayleigh_gains(key, m)
            return channel.truncated_inversion_power(
                h, self.cfg.fading_threshold)

        def silent_state(self, g, state, new_state):
            return (g + state).astype(new_state.dtype)
"""
from __future__ import annotations

import copy
import dataclasses
from dataclasses import dataclass
from functools import cached_property
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp

from repro.configs.base import OTAConfig
from repro.core import channel, compression, fading, geometry, power
from repro.core.amp import amp_decode
from repro.core.projection import DenseProjector, make_projector
from repro.kernels import ops, ref
from repro.robust import faults


# ---------------------------------------------------------------------------
# MAC context: where a round runs (axes, groups, fading, perf knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MACContext:
    """Topology and channel context threaded through encode/decode.

    One context describes one placement of the MAC: which mesh axes act as
    OTA devices, which shard the d-vector, how devices group into edge
    sites, and the per-device received-power factor (1.0 on the AWGN MAC;
    ``h_m^2`` under truncated-inversion fading, 0 in a deep fade).
    """
    m: int = 1                                   # effective OTA device count
    device_axes: Tuple[str, ...] = ()            # manual axes = MAC users
    shard_axes: Tuple[str, ...] = ()             # manual axes sharding d
    groups: Optional[Tuple[Tuple[int, ...], ...]] = None   # edge-site groups
    fading: str = "none"                         # descriptive channel model
    csi: str = "perfect"                         # descriptive CSI model
    p_factor: Any = 1.0                          # received-power scale (traced)
    # slice-driver geometry / perf knobs (defaults = paper-faithful)
    d_pad: int = 0                               # global padded dimension
    p_scale: float = 1.0                         # power share of this frame
    key_salt: int = 0                            # decorrelates sub-frames
    sample_per_shard: int = 4096                 # threshold sample budget
    chunk_blocks: int = 8                        # A-matrix working set
    frame_dtype: Any = None                      # psum analog bodies in bf16
    shard_decode: bool = False                   # split PS AMP across devices
    use_kernel: bool = False                     # Pallas projection/AMP path
    # hierarchical MAC: each edge-site group receives its own AWGN (the
    # partial OTA sums combine over the backhaul; repro.population.hierarchy)
    site_mac: bool = False
    site_noise_scale: Any = 1.0                  # per-site variance scale

    @property
    def group_size(self) -> int:
        return len(self.groups[0]) if self.groups else 1

    def with_p_factor(self, p_factor) -> "MACContext":
        return dataclasses.replace(self, p_factor=p_factor)


def axis_size(ax: str) -> int:
    """Static size of a manual mesh axis (portable across jax versions)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)


def shard_info(shard_axes: Sequence[str]):
    """(shard_idx, n_shards) of the calling device along the manual axes."""
    n_shards = 1
    shard_idx = jnp.zeros((), jnp.uint32)
    for ax in shard_axes:
        sz = axis_size(ax)
        shard_idx = shard_idx * sz + jax.lax.axis_index(ax).astype(jnp.uint32)
        n_shards *= sz
    return shard_idx, n_shards


class ChannelDraw(NamedTuple):
    """One round's channel realisation, as seen by a driver.

    ``p_factor``/``active`` are the pre-existing truncated-inversion pair
    (received-power scale inside ``encode``; transmit-set membership).  The
    two optional fields carry what imperfect-CSI channels add on top:
    ``gain`` is a per-device amplitude applied to the *encoded frame* (the
    misalignment ``Re(h/h_hat)`` under estimated inversion, the combiner
    gain under blind transmission — ``None`` means exactly 1 and preserves
    the legacy bitwise path), and ``noise_scale`` is a scalar multiplier on
    the AWGN variance (the blind PS combiner's noise enhancement; ``None``
    means exactly 1).
    """
    p_factor: jnp.ndarray                        # (m,) received-power factor
    active: jnp.ndarray                          # (m,) bool transmit set
    gain: Optional[jnp.ndarray] = None           # (m,) frame amplitude
    noise_scale: Optional[jnp.ndarray] = None    # scalar sigma^2 multiplier


# ---------------------------------------------------------------------------
# the Scheme contract + registry
# ---------------------------------------------------------------------------

SCHEME_REGISTRY: Dict[str, Type["Scheme"]] = {}

#: the five schemes evaluated in the paper's §VI figures
PAPER_SCHEMES = ("ideal", "a_dsgd", "d_dsgd", "signsgd", "qsgd")


def register_scheme(name: str):
    """Class decorator: register a Scheme subclass under ``name``."""
    def deco(cls: Type["Scheme"]) -> Type["Scheme"]:
        cls.name = name
        SCHEME_REGISTRY[name] = cls
        return cls
    return deco


def get_scheme(cfg: OTAConfig, d: int, m: int) -> "Scheme":
    """Resolve ``cfg.scheme`` through the registry and build the scheme.

    Back-compat promotion: ``scheme="a_dsgd"`` with ``fading="rayleigh"``
    (the pre-registry spelling) resolves to the ``a_dsgd_fading`` scheme.
    """
    name = cfg.scheme
    if name == "a_dsgd" and cfg.fading == "rayleigh":
        name = "a_dsgd_fading"
    try:
        cls = SCHEME_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scheme {name!r}; registered: "
            f"{', '.join(sorted(SCHEME_REGISTRY))}") from None
    return cls(cfg, d, m)


class Scheme:
    """Base class: common state/schedule plumbing + the generic hooks.

    Subclasses override :meth:`encode` / :meth:`decode` (and optionally the
    slice hooks and the fading hooks).  ``analog`` schemes superpose real
    frames on the Gaussian MAC (AWGN added by the driver); non-analog
    schemes (ideal benchmark, digital baselines) aggregate noiselessly —
    their channel impairment is the bit budget baked into the q schedule.
    """

    name: str = "?"
    analog: bool = False
    #: descriptive CSI model of the scheme's channel (MACContext.csi)
    csi: str = "perfect"

    def __init__(self, cfg: OTAConfig, d: int, m: int):
        self.cfg = cfg
        self.d = d
        self.m = m
        self._p_np = power.schedule_array(cfg.total_steps, cfg.p_avg,
                                          cfg.power_schedule)
        self.p_sched = jnp.asarray(self._p_np, jnp.float32)
        # channel-model scalars: these enter the round as data (compares /
        # multiplies), so the sweep engine can swap them per grid point via
        # with_overrides and vmap whole fading grids on one trace
        self.fading_threshold = jnp.float32(cfg.fading_threshold)
        self.csi_err_var = jnp.float32(cfg.csi_err_var)
        self.fading_rho = jnp.float32(cfg.fading_rho)
        #: run-level key anchoring the static / gauss_markov gain streams
        self.fading_key = fading.fading_base_key(cfg.seed)
        # geometry / scheduling scalars: traced like the channel scalars
        # above, so radius / path-loss / subband grids vmap on one program
        # (SCALAR_VMAP_AXES in repro.experiments.sweep; docs/DESIGN.md §12)
        self.cell_radius = jnp.float32(cfg.cell_radius)
        self.path_loss_exp = jnp.float32(cfg.path_loss_exp)
        self.n_subbands = jnp.float32(cfg.n_subbands)
        #: run-level key anchoring the device placement (geometry axis)
        self.geometry_key = geometry.geometry_base_key(cfg.seed)
        # robustness scalars: like the channel scalars above, these enter
        # the round as data, so fault/defence grids vmap on one program
        # (ROBUST_VMAP_AXES in repro.experiments.sweep); the *kinds*
        # (byz_attack / fault_kind / aggregator / clip_power) are static
        self.byzantine_frac = jnp.float32(cfg.byzantine_frac)
        self.byz_scale = jnp.float32(cfg.byz_scale)
        self.fault_rate = jnp.float32(cfg.fault_rate)
        self.erasure_prob = jnp.float32(cfg.erasure_prob)
        self.trim_frac = jnp.float32(cfg.trim_frac)
        self.norm_cap = jnp.float32(cfg.norm_cap)
        self.power_cap = jnp.float32(cfg.power_cap)
        #: run-level key anchoring the persistent Byzantine membership
        self.fault_key = faults.fault_base_key(cfg.seed)

    # ------------------------------------------------------------- state
    def init_state(self, d: Optional[int] = None) -> jnp.ndarray:
        """Per-device error accumulator Delta_m(0) = 0 (paper Alg. 1)."""
        return jnp.zeros((self.d if d is None else d,),
                         jnp.dtype(self.cfg.state_dtype))

    def channel_dim(self, d: Optional[int] = None) -> int:
        """Channel uses consumed per round for a d-dim gradient."""
        raise NotImplementedError

    def with_overrides(self, **attrs) -> "Scheme":
        """Shallow copy with attributes replaced — the sweep-engine hook.

        ``repro.experiments`` vmaps whole sweep grids through one trace by
        swapping the *schedule arrays* (``p_sched``, and ``q_sched`` for the
        digital schemes) for batched tracers per grid point; everything
        shape-defining (projector, k, q_max) stays on the copy untouched.
        Call inside the traced function so the tracers bind per trace.
        """
        new = copy.copy(self)
        for name, value in attrs.items():
            if not hasattr(new, name):
                raise AttributeError(
                    f"scheme {self.name!r} has no attribute {name!r} to "
                    "override")
            setattr(new, name, value)
        return new

    def p_t(self, step, p_factor=1.0) -> jnp.ndarray:
        """P_t for this step, scaled by the device's received-power factor."""
        p = self.p_sched[jnp.minimum(step, self.p_sched.shape[0] - 1)]
        return p * jnp.asarray(p_factor, jnp.float32)

    # ----------------------------------------------------- fading hooks
    @cached_property
    def fading_spec(self) -> fading.FadingSpec:
        """Static channel-model description (process / window / antennas),
        tagged with this scheme's CSI model."""
        return dataclasses.replace(fading.spec_from_cfg(self.cfg),
                                   csi=self.csi)

    def gains(self, key: jnp.ndarray, step, m: int):
        """Complex gains (re, im) for this round under cfg.fading_process —
        pure in (key, step), so it evaluates identically inside a compiled
        scan, in the looped reference, and under vmap."""
        return fading.process_gains(self.fading_spec, self.fading_key, key,
                                    step, m, rho=self.fading_rho)

    def device_factors(self, key: jnp.ndarray, m: int):
        """(received-power factor, participation mask) per device."""
        return jnp.ones((m,)), jnp.ones((m,), bool)

    # --------------------------------------------------- geometry hooks
    @property
    def geometry_on(self) -> bool:
        """Static gate for the geometry composition: with ``"none"`` no
        geometry op enters the trace (pre-geometry goldens stay bitwise)."""
        return self.cfg.geometry != "none"

    @cached_property
    def geometry_spec(self) -> geometry.GeometrySpec:
        """Static cell-geometry description (placement model / antennas)."""
        return geometry.spec_from_cfg(self.cfg)

    def geometry_gains(self, m: int) -> jnp.ndarray:
        """(m,) run-constant large-scale gains of the device placement —
        pure in the run-level ``geometry_key``; ``cell_radius`` and
        ``path_loss_exp`` are the traced scheme attributes, so
        ``with_overrides`` vmaps whole radius / path-loss grids."""
        return geometry.large_scale_gains(
            self.geometry_key, m, self.cell_radius, self.path_loss_exp,
            self.geometry_spec)

    def small_scale_draw(self, key: jnp.ndarray, step, m: int,
                         mask=None) -> ChannelDraw:
        """The small-scale (fading/CSI) part of the round's realisation.

        The base implementation wraps the legacy :meth:`device_factors`
        pair; channel-aware schemes override *this* hook to add fading,
        CSI error or PS-side combining — :meth:`channel_draw` then
        composes the geometry layer on top, so every scheme inherits the
        geometry axis without touching it.
        """
        p_factor, active = self.device_factors(key, m)
        return ChannelDraw(p_factor, active)

    def channel_draw(self, key: jnp.ndarray, step, m: int,
                     mask=None) -> ChannelDraw:
        """One round's channel realisation (the driver-facing hook).

        Composes the scheme's :meth:`small_scale_draw` with the run-
        constant large-scale geometry gains (``p_factor *= g_m``, the
        standard large-scale/small-scale factorisation) when the static
        ``cfg.geometry`` gate is on; with geometry off this *is* the
        small-scale draw — no extra op, bitwise the pre-geometry path.
        ``key`` is the fading-salted round key (``fold_in(round_key,
        2)``); ``step`` feeds the time-correlated processes.  ``mask``
        (optional, (m,) bool) marks which of the m padded devices
        physically exist — per-device draws can ignore it (masked frames
        are zeroed by the driver anyway), but draws that couple devices
        (the blind PS combiner) must exclude phantom rows.
        """
        draw = self.small_scale_draw(key, step, m, mask=mask)
        if self.geometry_on:
            draw = draw._replace(
                p_factor=draw.p_factor * self.geometry_gains(m))
        return draw

    def cohort_channel_draw(self, key: jnp.ndarray, step,
                            cohort: jnp.ndarray, m_total: int,
                            mask=None) -> ChannelDraw:
        """The K-cohort's rows of the full-population channel realisation.

        Evaluates :meth:`channel_draw` at the population size ``m_total``
        from the same salted key and gathers the cohort's rows — a K < M
        cohort sees exactly the channels the full simulation would have
        dealt those devices, and a K == M cohort (``cohort == arange(M)``)
        reproduces the legacy draw bitwise.  Costs O(m_total) scalars per
        round, never O(m_total * d).  ``mask`` (K,) bool marks live cohort
        rows; it is scattered to the full population so device-coupled
        draws (the blind PS combiner) see the true transmitter set.
        """
        full_mask = None
        if mask is not None:
            full_mask = jnp.zeros((m_total,), bool).at[cohort].set(mask)
        draw = self.channel_draw(key, step, m_total, mask=full_mask)

        def take(v):
            return None if v is None else jnp.take(v, cohort, axis=0)

        return ChannelDraw(take(draw.p_factor), take(draw.active),
                           gain=take(draw.gain),
                           noise_scale=draw.noise_scale)

    def silent_state(self, g: jnp.ndarray, state: jnp.ndarray,
                     new_state: jnp.ndarray) -> jnp.ndarray:
        """Error state of a non-participating (deep-fade / dropout) device."""
        return new_state

    # ------------------------------------------------------ fault hooks
    @property
    def robust_on(self) -> bool:
        """Static gate for the fault-injection path: the robust master
        switch, or any nonzero *configured* fault rate (a swept rate axis
        rides ``robust=True`` — the sweep engine auto-promotes it)."""
        cfg = self.cfg
        return bool(cfg.robust or cfg.byzantine_frac > 0
                    or cfg.fault_rate > 0 or cfg.erasure_prob > 0)

    def fault_draw(self, key: jnp.ndarray, step, m: int) -> faults.FaultDraw:
        """One round's fault realisation (pure in the salted round key).

        ``key`` is the fault-salted round key (``fold_in(round_key,
        faults.SALT_FAULT)``) — callers own the salt, matching
        :meth:`channel_draw`.  Rates are the traced scheme attributes, so
        ``with_overrides`` vmaps them; the Byzantine set threshold draws
        from the run-level ``fault_key`` (persistent, nested in the
        fraction)."""
        return faults.fault_draw(self.fault_key, key, m,
                                 byzantine_frac=self.byzantine_frac,
                                 fault_rate=self.fault_rate,
                                 erasure_prob=self.erasure_prob,
                                 fault_kind=self.cfg.fault_kind)

    def cohort_fault_draw(self, key: jnp.ndarray, step,
                          cohort: jnp.ndarray,
                          m_total: int) -> faults.FaultDraw:
        """The K-cohort's rows of the full-population fault realisation —
        the fault analogue of :meth:`cohort_channel_draw`: a K < M cohort
        sees exactly the faults the full simulation would have dealt those
        devices, and K == M reproduces :meth:`fault_draw` bitwise."""
        return faults.take_rows(self.fault_draw(key, step, m_total), cohort)

    # ---------------------------------------------------- encode/decode
    def encode(self, g: jnp.ndarray, state: jnp.ndarray, step, key,
               ctx: Optional[MACContext] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Device-side: (d,) gradient -> channel frame. Returns
        ``(frame, new_state, metrics)``."""
        raise NotImplementedError

    def decode(self, y: jnp.ndarray, step,
               ctx: Optional[MACContext] = None) -> jnp.ndarray:
        """PS-side: MAC output -> average-gradient estimate."""
        m = ctx.m if ctx is not None else self.m
        return y / m

    # ------------------------------------------------------ slice hooks
    # Optional: schemes that can run on gradient *slices* (the fully-
    # sharded driver in core/distributed.py) implement these.  The frame is
    # a dict with a "body" array (psum'd over the device axes, optionally
    # in a narrow dtype) and optional "slots" scalars (always f32).
    def encode_slice(self, g_slice, state_slice, step, key, ctx: MACContext):
        raise NotImplementedError(
            f"scheme {self.name!r} does not support the sharded slice "
            "driver (needs a slice-local encode); use the simulated or "
            "round_sharded drivers")

    def decode_slice(self, y: Dict[str, jnp.ndarray], step, ctx: MACContext):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# ideal (error-free shared link, the paper's benchmark)
# ---------------------------------------------------------------------------


@register_scheme("ideal")
class IdealScheme(Scheme):
    """y = sum_m g_m / M over an error-free link."""

    def channel_dim(self, d: Optional[int] = None) -> int:
        return self.d if d is None else d

    def encode(self, g, state, step, key, ctx=None):
        return g.astype(jnp.float32), state, {}

    # slice driver: the MAC psum *is* the aggregation
    def encode_slice(self, g_slice, state_slice, step, key, ctx):
        return {"body": g_slice}, state_slice, {"p_t": jnp.zeros(())}

    def decode_slice(self, y, step, ctx):
        return y["body"] / ctx.m


# ---------------------------------------------------------------------------
# A-DSGD (paper §IV): EF + top-k + compressive projection + analog MAC + AMP
# ---------------------------------------------------------------------------


@register_scheme("a_dsgd")
class ADSGDScheme(Scheme):
    """Analog DSGD: the paper's over-the-air scheme (§IV, §IV-A)."""

    analog = True

    @cached_property
    def projector(self):
        return make_projector(self.cfg, self.d)

    @cached_property
    def k(self) -> int:
        if isinstance(self.projector, DenseProjector):
            return self.cfg.k_for(self.d)
        # blocked: k scales with the realised channel dimension
        return max(1, int(self.cfg.k_frac * self.projector.out_dim))

    def channel_dim(self, d: Optional[int] = None) -> int:
        # body + mean slot + scale slot (static frame layout, channel.py)
        if d is not None and d != self.d:
            raise ValueError(
                "an A-DSGD scheme's channel dimension is fixed by its "
                f"projector (built for d={self.d}); call get_scheme with "
                f"d={d} to size a different gradient")
        return self.projector.out_dim + 2

    def _projector_for(self, ctx: Optional[MACContext]):
        """The projector honouring the MACContext's use_kernel override
        (dense projectors have no kernel path; cfg.use_kernel is baked into
        the cached projector, so only an upgrade needs a copy)."""
        proj = self.projector
        if (ctx is not None and ctx.use_kernel
                and not isinstance(proj, DenseProjector)
                and not proj.use_kernel):
            proj = dataclasses.replace(proj, use_kernel=True)
        return proj

    def encode(self, g, state, step, key, ctx=None):
        cfg = self.cfg
        g = g.astype(jnp.float32)
        p_t = self.p_t(step, ctx.p_factor if ctx is not None else 1.0)
        g_ec = g + state.astype(jnp.float32)
        projector = self._projector_for(ctx)
        if isinstance(projector, DenseProjector):
            g_sp = compression.top_k_sparsify(g_ec, self.k)
            new_state = g_ec - g_sp
        else:
            tau = compression.sampled_topk_threshold(g_ec, self.k, key)
            g_sp, new_state = ops.ef_sparsify(
                g, state.astype(jnp.float32), tau,
                use_kernel=self._use_kernel(ctx) if ctx is not None
                else cfg.use_kernel)
        g_tilde = projector.project(g_sp)
        use_mr = (jnp.asarray(step) < cfg.mean_removal_steps)
        frame, alpha = channel.make_frame(g_tilde, p_t, use_mr)
        metrics = {"alpha": alpha, "p_t": p_t,
                   "frame_power": channel.frame_power(frame)}
        return frame, new_state.astype(state.dtype), metrics

    def decode(self, y, step, ctx=None):
        use_mr = (jnp.asarray(step) < self.cfg.mean_removal_steps)
        y_body = channel.ps_normalize(y, use_mr)
        return amp_decode(y_body, self._projector_for(ctx),
                          self.cfg.amp_iters)

    def silent_state(self, g, state, new_state):
        # a device that could not transmit (deep fade, mid-round dropout)
        # banks its whole update — nothing of g_sp reached the MAC.  On
        # the AWGN channel every device is active, so this branch is never
        # *selected*; the fading subclasses inherit it.
        return (g + state).astype(new_state.dtype)

    # ------------------------------------------------------ slice hooks
    # The fully-sharded pipeline (train/trainer.py phase 2): every device
    # owns a (d_pad / n_shards) slice.  EF, thresholding, projection and the
    # power scalars are slice-local; cross-shard coordination is a 65k-
    # sample all_gather and scalar psums.  Per-shard measurement matrices
    # derive from a shard-folded seed (the PS uses the same fold).

    def _slice_seed(self, ctx: MACContext):
        shard_idx, n_shards = shard_info(ctx.shard_axes)
        return ref.splitmix32(jnp.uint32(self.cfg.seed)
                              ^ shard_idx.astype(jnp.uint32)), shard_idx

    def _use_kernel(self, ctx: MACContext) -> bool:
        """Pallas knob: OTAConfig.use_kernel, or the MACContext override."""
        return bool(self.cfg.use_kernel) or ctx.use_kernel

    def encode_slice(self, g_slice, state_slice, step, key, ctx):
        from repro.core.distributed import proj_forward, psum_all
        cfg = self.cfg
        d_pad = ctx.d_pad
        d_local = g_slice.shape[0]

        # --- error feedback + sampled global threshold ---------------------
        g_ec = g_slice + state_slice.astype(jnp.float32)
        k = max(1, int(cfg.k_frac * cfg.s_frac * d_pad))
        stride = max(1, d_local // ctx.sample_per_shard)
        n_s = d_local // stride
        local_sample = jnp.abs(jax.lax.slice_in_dim(g_ec, 0, n_s * stride,
                                                    stride, axis=0))
        all_samples = (jax.lax.all_gather(local_sample,
                                          ctx.shard_axes).reshape(-1)
                       if ctx.shard_axes else local_sample)
        q = 1.0 - k / d_pad
        tau = jnp.quantile(all_samples, q)
        keep = jnp.abs(g_ec) >= tau
        g_sp = jnp.where(keep, g_ec, 0.0)
        new_state = (g_ec - g_sp).astype(state_slice.dtype)

        # --- blocked projection (per-shard folded seed) --------------------
        c = cfg.block_size
        s_block = max(2, int(round(cfg.s_frac * c)))
        n_blocks_local = d_local // c
        seed_u32, _ = self._slice_seed(ctx)
        yb = proj_forward(g_sp.reshape(n_blocks_local, c), seed_u32, s_block,
                          ctx.chunk_blocks,
                          use_kernel=self._use_kernel(ctx))  # (nb_local, s_b)

        # --- power scaling (paper eq. 13/22; scalars psum'd over shards) ---
        # ctx.p_factor carries this device's fading received-power factor
        p_t = self.p_t(step, ctx.p_factor) * ctx.p_scale
        use_mr = (jnp.asarray(step)
                  < cfg.mean_removal_steps).astype(jnp.float32)
        s_tilde = float((d_pad // c) * s_block)          # global channel dim
        mu = use_mr * psum_all(jnp.sum(yb), ctx.shard_axes) / s_tilde
        energy = psum_all(jnp.sum(yb * yb), ctx.shard_axes)
        energy_az = energy - (s_tilde - 1.0) * mu * mu + 1.0
        alpha = p_t / jnp.maximum(energy_az, 1e-12)
        ra = jnp.sqrt(alpha)
        frame = {"body": ra * (yb - mu), "slots": jnp.stack([ra * mu, ra])}
        metrics = {"alpha": alpha, "p_t": p_t, "tau": tau,
                   "frame_power": alpha * energy_az}
        return frame, new_state, metrics

    def decode_slice(self, y, step, ctx):
        from repro.core.distributed import amp_blocked
        cfg = self.cfg
        body, slots = y["body"], y["slots"]
        use_mr = (jnp.asarray(step)
                  < cfg.mean_removal_steps).astype(jnp.float32)
        # the clean scale slot is sum_m sqrt(alpha_m) > 0 by construction;
        # a noise-dominated reading falls back to 1.0 so it can neither
        # flip the observation's sign nor amplify it unboundedly (same rule
        # as channel.ps_normalize on the dense path)
        scale = jnp.where(slots[1] > channel.SCALE_SLOT_FLOOR, slots[1], 1.0)
        y_norm = (body + use_mr * slots[0]) / scale
        seed_u32, _ = self._slice_seed(ctx)
        use_kernel = self._use_kernel(ctx)
        c = cfg.block_size
        if ctx.shard_decode and ctx.device_axes:
            # the y slice is identical on every device row after the psum —
            # decode 1/M of its blocks per row and all-gather the results;
            # block ids stay global via the id offset (encode used global
            # ids, so a row-salted projector would be wrong).
            n_rows = 1
            row_idx = jnp.zeros((), jnp.int32)
            for ax in ctx.device_axes:
                sz = axis_size(ax)
                row_idx = row_idx * sz + jax.lax.axis_index(ax)
                n_rows *= sz
            nb = y_norm.shape[0]
            nb_pad = -(-nb // n_rows) * n_rows
            y_p = jnp.pad(y_norm, ((0, nb_pad - nb), (0, 0)))
            per = nb_pad // n_rows
            y_mine = jax.lax.dynamic_slice_in_dim(y_p, row_idx * per, per, 0)
            x_mine = amp_blocked(y_mine, seed_u32, c, cfg.amp_iters,
                                 ctx.chunk_blocks,
                                 id_offset=(row_idx * per).astype(jnp.uint32),
                                 use_kernel=use_kernel)
            xg = jax.lax.all_gather(x_mine, ctx.device_axes, tiled=True)
            return xg[:nb].reshape(-1)
        return amp_blocked(y_norm, seed_u32, c, cfg.amp_iters,
                           ctx.chunk_blocks,
                           use_kernel=use_kernel).reshape(-1)


# ---------------------------------------------------------------------------
# A-DSGD over fading MACs (follow-ups 1907.09769 / 1907.03909): truncated
# inversion under perfect / estimated CSI, and CSI-free blind transmission
# ---------------------------------------------------------------------------


@register_scheme("a_dsgd_fading")
class ADSGDFadingScheme(ADSGDScheme):
    """A-DSGD under Rayleigh fading with truncated channel inversion
    (perfect CSI, arXiv:1907.09769): devices below the fade threshold stay
    silent this round (their whole update accumulates into the error
    state); the rest pre-invert, so the usable received power becomes
    ``P_t * h_m^2``.  The gain *process* (``cfg.fading_process``: block-flat
    ``static``, per-round ``iid``, time-correlated ``gauss_markov``) comes
    from :mod:`repro.core.fading`; ``iid`` is bitwise the original
    per-round Rayleigh draw."""

    def device_factors(self, key, m):
        # legacy spelling of the iid draw — kept because it is the module
        # docstring's ~10-line extension example; channel_draw generalises
        # it across fading processes
        h = channel.rayleigh_gains(key, m)
        return channel.truncated_inversion_power(h, self.fading_threshold)

    def small_scale_draw(self, key, step, m, mask=None):
        re, im = self.gains(key, step, m)
        h = fading.magnitude(re, im)
        p_factor, active = channel.truncated_inversion_power(
            h, self.fading_threshold)
        return ChannelDraw(p_factor, active)

    def silent_state(self, g, state, new_state):
        # a silent (deep-fade) device accumulates its whole update
        return (g + state).astype(new_state.dtype)


@register_scheme("a_dsgd_csi_err")
class ADSGDCSIErrScheme(ADSGDFadingScheme):
    """Truncated inversion driven by a *noisy* CSI estimate.

    The device only sees ``h_hat = h + e``, ``e ~ CN(0, csi_err_var)``
    (an MMSE-style estimation error): it makes its truncation decision and
    pre-inverts with ``h_hat``, so the frame arrives scaled by the
    misalignment ``Re(h / h_hat)`` — residual fading that survives decode —
    while the power budget follows ``|h_hat|^2``.  With ``csi_err_var == 0``
    every quantity degrades bitwise to :class:`ADSGDFadingScheme` (pinned by
    the ``a_dsgd_csi_err0`` golden).
    """

    csi = "noisy"

    def small_scale_draw(self, key, step, m, mask=None):
        re, im = self.gains(key, step, m)
        est_re, est_im = fading.csi_estimate(
            re, im, jax.random.fold_in(key, 3), self.csi_err_var)
        h_est = fading.magnitude(est_re, est_im)
        p_factor, active = channel.truncated_inversion_power(
            h_est, self.fading_threshold)
        gain = fading.misalignment_gain(re, im, est_re, est_im,
                                        self.csi_err_var)
        return ChannelDraw(p_factor, active, gain=gain)


@register_scheme("a_dsgd_blind")
class ADSGDBlindScheme(ADSGDScheme):
    """A-DSGD with blind transmitters (no CSIT, arXiv:1907.03909).

    Devices cannot invert a gain they do not know: every device transmits
    its plain power-scaled frame (full transmit set, ``p_factor = 1``), and
    alignment is recovered at the PS, whose K antennas combine the
    superposed observations against the known receive CSI
    (:func:`repro.core.fading.blind_combiner_stats`).  Each frame then
    carries a per-device effective gain ``1 + O(sqrt(M/K))`` and the AWGN
    variance is enhanced by ``~ M/K`` — both vanish as K grows (channel
    hardening), which is the paper's asymptotic result.  The decode is
    untouched: the analog scale slot arrives as ``sum_m g_m sqrt(alpha_m)``
    and absorbs the combiner's average gain exactly like the fading
    alpha-spread it was designed for.
    """

    csi = "none"

    def small_scale_draw(self, key, step, m, mask=None):
        k_ant = self.fading_spec.ps_antennas
        re, im = self.gains(key, step, m * k_ant)
        re, im = re.reshape(m, k_ant), im.reshape(m, k_ant)
        if mask is not None:
            # phantom (masked-out) devices do not exist physically: their
            # channel rows must not enter the PS combiner f_k = sum_m h_mk,
            # so an m_active sweep sees the m_eff-transmitter combiner
            # statistics, not the padded cohort's
            live = mask.astype(re.dtype)[:, None]
            re, im = re * live, im * live
        gain, noise_scale = fading.blind_combiner_stats(re, im)
        return ChannelDraw(jnp.ones((m,)), jnp.ones((m,), bool),
                           gain=gain, noise_scale=noise_scale)


# ---------------------------------------------------------------------------
# digital baselines (paper §III, §VI): quantize to the MAC bit budget R_t
# ---------------------------------------------------------------------------


class _BitBudgetScheme(Scheme):
    """Shared plumbing for the digital schemes: the per-step budget q_t is
    precomputed on the host from the MAC capacity R_t (paper eq. 8/9)."""

    def __init__(self, cfg: OTAConfig, d: int, m: int):
        super().__init__(cfg, d, m)
        q_np = self.build_q_schedule(m, self._p_np)
        self.q_sched = jnp.asarray(q_np, jnp.int32)
        self.q_max = int(max(int(q_np.max()), 1))

    def build_q_schedule(self, m: int, p_np) -> Any:
        """Host-precomputed q_t array for an (m, P_t) pair — the single
        source of the budget/cap rule, shared with the sweep engine
        (repro.experiments.sweep precomputes per-grid-point schedules
        with the effective device count and vmaps them)."""
        return compression.digital_q_schedule(
            self.d, self.cfg.s_for(self.d), m, p_np, self.cfg.sigma2,
            scheme=self.name, l_q=self.cfg.quant_bits,
            q_cap=min(self.d // 2, 1 << 16))

    def channel_dim(self, d: Optional[int] = None) -> int:
        return self.cfg.s_for(self.d if d is None else d)

    def q_t(self, step) -> jnp.ndarray:
        return self.q_sched[jnp.minimum(step, self.q_sched.shape[0] - 1)]

    def encode(self, g, state, step, key, ctx=None):
        g = g.astype(jnp.float32)
        p_t = self.p_t(step, ctx.p_factor if ctx is not None else 1.0)
        q_t = self.q_t(step)
        v_q, new_state = self.compress(g, state, q_t, key)
        return v_q, new_state, {"q_t": q_t, "p_t": p_t}

    def compress(self, g, state, q_t, key):
        raise NotImplementedError


@register_scheme("d_dsgd")
class DDSGDScheme(_BitBudgetScheme):
    """Digital DSGD: error feedback + SBC quantization (paper §III)."""

    def compress(self, g, state, q_t, key):
        g_ec = g + state.astype(jnp.float32)
        v_q = compression.sbc_quantize(g_ec, q_t, self.q_max)
        return v_q, (g_ec - v_q).astype(state.dtype)

    def silent_state(self, g, state, new_state):
        # a D-DSGD device that failed mid-round banks its whole update
        # (error feedback over the digital link); only the fault-injection
        # path selects this — the legacy digital drivers never drop devices
        return (g + state).astype(new_state.dtype)


@register_scheme("signsgd")
class SignSGDScheme(_BitBudgetScheme):
    """SignSGD [16] adapted to the bit budget (paper eq. 43)."""

    def compress(self, g, state, q_t, key):
        return compression.signsgd_compress(g, q_t, self.q_max), state


@register_scheme("qsgd")
class QSGDScheme(_BitBudgetScheme):
    """QSGD [2] adapted to the bit budget (paper eq. 44)."""

    def compress(self, g, state, q_t, key):
        return compression.qsgd_compress(g, q_t, self.q_max,
                                         self.cfg.quant_bits, key), state


def registered_schemes() -> Tuple[str, ...]:
    """Every registered scheme name (registration order), evaluated live."""
    return tuple(SCHEME_REGISTRY)


def __getattr__(name: str):
    # SCHEMES is a live view of the registry: schemes registered after this
    # module imported (e.g. user @register_scheme) still appear.
    if name == "SCHEMES":
        return registered_schemes()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# generic drivers (scheme-agnostic: behaviour comes from the hooks)
# ---------------------------------------------------------------------------


def channel_amp(draw: ChannelDraw, dtype=jnp.float32) -> jnp.ndarray:
    """Per-device amplitude of the received frame: the transmit mask, times
    the channel gain when the draw carries one.  ``gain=None`` means exactly
    1, so the expression stays the 0/1 mask and the legacy path is bitwise
    (multiplying by the cast mask is IEEE-identical to multiplying by the
    bool — promotion performs the same cast)."""
    active = draw.active.astype(dtype)
    return active if draw.gain is None else draw.gain * active


def apply_channel_gain(frames: jnp.ndarray, draw: ChannelDraw) -> jnp.ndarray:
    """Silence inactive devices and apply the per-device channel gain to a
    stacked (m, s) frame batch (the simulated/masked drivers)."""
    return frames * channel_amp(draw, frames.dtype)[..., None]


def round_sigma2(scheme: Scheme, draw: ChannelDraw):
    """This round's AWGN variance: cfg.sigma2, under the channel's traced
    noise enhancement when the draw carries one (blind PS combining)."""
    if draw.noise_scale is None:
        return scheme.cfg.sigma2
    return scheme.cfg.sigma2 * draw.noise_scale


def encode_round(scheme: Scheme, grads: jnp.ndarray, deltas: jnp.ndarray,
                 step, key: jnp.ndarray, ctx: MACContext):
    """The device/channel half of :func:`round_simulated`: per-device
    encode, channel gain, MAC superposition (+AWGN for analog schemes).

    Returns ``(y, new_deltas, metrics, draw)`` — everything up to (but not
    including) the PS-side ``scheme.decode``.  Splitting here is what lets
    the streamed LLM driver (``train/fedllm.py``) double-buffer: while the
    PS decodes chunk ``i-1``, the devices encode and transmit chunk ``i``.
    ``round_simulated`` composes this with the decode, so the split is
    bitwise-invisible to every existing driver and golden.
    """
    m = grads.shape[0]
    dev_keys = jax.random.split(jax.random.fold_in(key, 1), m)
    draw = scheme.channel_draw(jax.random.fold_in(key, 2), step, m)
    active = draw.active
    frames, new_deltas, metrics = jax.vmap(
        lambda g, dl, kk, pf: scheme.encode(g, dl, step, kk,
                                            ctx.with_p_factor(pf)))(
            grads, deltas, dev_keys, draw.p_factor)
    if scheme.analog:
        frames = apply_channel_gain(frames, draw)
        new_deltas = jnp.where(active[:, None], new_deltas,
                               scheme.silent_state(grads, deltas, new_deltas))
        y = channel.mac_sum(frames, jax.random.fold_in(key, 0),
                            round_sigma2(scheme, draw))
    else:
        y = jnp.sum(frames, axis=0)
    return y, new_deltas, metrics, draw


def round_simulated(scheme: Scheme, grads: jnp.ndarray, deltas: jnp.ndarray,
                    step, key: jnp.ndarray,
                    ctx: Optional[MACContext] = None):
    """M devices on one host. grads/deltas: (M, d). Returns
    ``(ghat, new_deltas, metrics)``; the MAC is a sum over the leading axis
    (plus AWGN for analog schemes)."""
    if ctx is None:
        ctx = MACContext(m=scheme.m, fading=scheme.cfg.fading,
                         csi=scheme.csi)
    y, new_deltas, metrics, draw = encode_round(scheme, grads, deltas,
                                                step, key, ctx)
    ghat = scheme.decode(y, step, ctx)
    metrics = {k: jnp.mean(v) for k, v in metrics.items()}
    metrics["active_frac"] = jnp.mean(draw.active.astype(jnp.float32))
    if draw.gain is not None:
        metrics["chan_gain"] = jnp.mean(draw.gain)
    if draw.noise_scale is not None:
        metrics["noise_scale"] = draw.noise_scale
    return ghat, new_deltas, metrics


def sharded_channel_draw(scheme: Scheme, key: jnp.ndarray, step,
                         ctx: MACContext) -> ChannelDraw:
    """This device's channel realisation inside a shard_map.

    Every manual device evaluates the *full-M* draw from the shared round
    key (salt 2, matching :func:`round_simulated`) and takes its own row —
    the realisation is common knowledge across devices, which is what the
    correlated processes and the blind PS combiner (whose per-device gain
    depends on everyone's channel) require, and the per-scalar cost of the
    M-row draw is noise next to the d-sized frame math.
    """
    dev_idx, _ = shard_info(ctx.device_axes)
    draw = scheme.channel_draw(jax.random.fold_in(key, 2), step, ctx.m)

    def take(v):
        if v is None:
            return None
        return jax.lax.dynamic_index_in_dim(v, dev_idx.astype(jnp.int32),
                                            keepdims=False)

    return ChannelDraw(take(draw.p_factor), take(draw.active),
                       gain=take(draw.gain), noise_scale=draw.noise_scale)


def round_sharded(scheme: Scheme, g_local: jnp.ndarray,
                  delta_local: jnp.ndarray, step, key: jnp.ndarray,
                  ctx: MACContext):
    """One aggregation round inside a shard_map (manual axes = devices).

    ``ctx.groups``: optional axis_index_groups for the *ideal* intra-site
    average (hierarchical edge-site mapping) over the last device axis; the
    MAC psum then runs over all manual devices and is divided by the group
    size (the scale slot absorbs any per-device alpha spread).
    """
    group_size = ctx.group_size
    if ctx.groups is not None:
        g_local = jax.lax.psum(g_local, ctx.device_axes[-1],
                               axis_index_groups=[list(g) for g in ctx.groups])
        g_local = g_local / group_size
    # distinct salts for the three RNG consumers (matching round_simulated):
    # fold 1 -> device-side encode randomness, fold 2 -> the channel draw,
    # fold 0 -> the channel AWGN
    if scheme.analog:
        draw = sharded_channel_draw(scheme, key, step, ctx)
        ctx = ctx.with_p_factor(draw.p_factor)
    frame, new_delta, metrics = scheme.encode(
        g_local, delta_local, step, jax.random.fold_in(key, 1), ctx)
    if scheme.analog:
        frame = frame * channel_amp(draw, frame.dtype)
        new_delta = jnp.where(draw.active, new_delta,
                              scheme.silent_state(g_local, delta_local,
                                                  new_delta))
    y = frame
    for ax in ctx.device_axes:
        y = jax.lax.psum(y, ax)
    if group_size > 1:
        y = y / group_size
    if scheme.analog:
        mac_key = jax.random.fold_in(key, 0)
        sigma2 = round_sigma2(scheme, draw)
        if ctx.site_mac and ctx.groups is not None and len(ctx.groups) > 1:
            # hierarchical MAC: every edge-site group's partial OTA sum
            # carries its own receiver AWGN, summed by the backhaul combine
            y = y + channel.site_awgn(mac_key, y.shape, sigma2,
                                      len(ctx.groups),
                                      site_noise_scale=ctx.site_noise_scale,
                                      dtype=y.dtype)
        else:
            y = y + channel.awgn(mac_key, y.shape, sigma2, y.dtype)
    ghat = scheme.decode(y, step, ctx)
    return ghat, new_delta, metrics
