"""Deprecated pre-registry aggregation API (one-PR grace period).

The aggregation layer now lives in :mod:`repro.core.schemes`: every scheme
is a registered class implementing ``init_state / encode / decode /
channel_dim`` (plus slice hooks), resolved by ``get_scheme(cfg, d, m)`` and
run by the generic drivers ``round_simulated`` / ``round_sharded`` /
``distributed.sharded_round``.  This module keeps the old surface working:

  * :func:`make_aggregator` — returns an :class:`Aggregator` shim wrapping
    the registry-resolved scheme.
  * ``SCHEMES`` / ``ANALOG_SCHEMES`` / ``DIGITAL_SCHEMES`` — re-exported
    name tuples (now derived from the registry).

New code should import from ``repro.core.schemes`` directly; this shim will
be removed next PR.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.configs.base import OTAConfig
from repro.core import schemes as _schemes
from repro.core.schemes import (  # noqa: F401  (re-exports)
    MACContext, PAPER_SCHEMES, Scheme, get_scheme, register_scheme,
    registered_schemes,
)

ANALOG_SCHEMES = ("a_dsgd", "a_dsgd_fading")
DIGITAL_SCHEMES = ("d_dsgd", "signsgd", "qsgd")


def __getattr__(name: str):
    if name == "SCHEMES":          # live view of the registry
        return registered_schemes()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class Aggregator:
    """Deprecated facade over a registered :class:`~repro.core.schemes.Scheme`.

    Exposes the pre-registry methods (``init_delta``, ``encode``, ``decode``,
    ``round_simulated``, ``round_sharded``) by delegating to the scheme
    object and the generic drivers.
    """

    def __init__(self, scheme: Scheme):
        self.scheme = scheme

    # -- old attribute surface ------------------------------------------------
    @property
    def cfg(self) -> OTAConfig:
        return self.scheme.cfg

    @property
    def d(self) -> int:
        return self.scheme.d

    @property
    def m(self) -> int:
        return self.scheme.m

    @property
    def projector(self):
        return getattr(self.scheme, "projector", None)

    @property
    def k(self) -> int:
        return getattr(self.scheme, "k", 0)

    @property
    def p_sched(self):
        return self.scheme.p_sched

    @property
    def q_sched(self):
        return getattr(self.scheme, "q_sched", None)

    @property
    def q_max(self) -> int:
        return getattr(self.scheme, "q_max", 0)

    # -- old method surface ---------------------------------------------------
    def init_delta(self) -> jnp.ndarray:
        return self.scheme.init_state()

    def encode(self, g, delta, step, key, p_factor=1.0
               ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
        ctx = MACContext(m=self.scheme.m, p_factor=p_factor)
        return self.scheme.encode(g, delta, step, key, ctx)

    def decode(self, y, step) -> jnp.ndarray:
        return self.scheme.decode(y, step)

    def round_simulated(self, grads, deltas, step, key):
        return _schemes.round_simulated(self.scheme, grads, deltas, step, key)

    def round_sharded(self, g_local, delta_local, step, key,
                      axis_names: Sequence[str],
                      groups: Optional[Sequence[Sequence[int]]] = None,
                      pre_average_groups=None):
        ctx = MACContext(
            m=self.scheme.m, device_axes=tuple(axis_names),
            groups=(tuple(tuple(g) for g in pre_average_groups)
                    if pre_average_groups is not None else None))
        return _schemes.round_sharded(self.scheme, g_local, delta_local,
                                      step, key, ctx)


def make_aggregator(cfg: OTAConfig, d: int, m: int) -> Aggregator:
    """Deprecated: use ``repro.core.schemes.get_scheme(cfg, d, m)``."""
    warnings.warn("make_aggregator is deprecated; use "
                  "repro.core.schemes.get_scheme", DeprecationWarning,
                  stacklevel=2)
    return Aggregator(get_scheme(cfg, d, m))
