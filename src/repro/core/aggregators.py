"""Gradient aggregation schemes (the paper's contribution, as a library).

Every scheme is an encode/decode pair around the wireless MAC:

  * ``ideal``   — error-free shared link (paper's benchmark): y = sum g / M.
  * ``a_dsgd``  — analog over-the-air (paper §IV): error feedback, top-k,
                  compressive projection, power scaling, MAC superposition,
                  AMP reconstruction; mean-removal variant (§IV-A).
  * ``d_dsgd``  — digital (paper §III): error feedback + SBC quantization
                  under the per-iteration MAC bit budget R_t (eq. 8/9).
  * ``signsgd`` — SignSGD [16] adapted to the bit budget (eq. 43).
  * ``qsgd``    — QSGD [2] adapted to the bit budget (eq. 44).

Two drivers share the same encode/decode:

  * :meth:`Aggregator.round_simulated` — M devices on one host (paper-scale
    benchmarks; the MAC is a sum over the leading axis).
  * :meth:`Aggregator.round_sharded` — inside a partial-manual shard_map; the
    MAC is ``lax.psum`` over the manual mesh axes (the TPU ICI plays the role
    of the superposing wireless channel), with optional hierarchical groups
    (``axis_index_groups``): intra-group aggregation is ideal (wired
    datacenter links within an edge site), the MAC runs across groups.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OTAConfig
from repro.core import channel, compression, power
from repro.core.amp import amp_decode
from repro.core.projection import BlockedProjector, DenseProjector, make_projector
from repro.kernels import ops

ANALOG_SCHEMES = ("a_dsgd",)
DIGITAL_SCHEMES = ("d_dsgd", "signsgd", "qsgd")
SCHEMES = ("ideal",) + ANALOG_SCHEMES + DIGITAL_SCHEMES


@dataclass(frozen=True)
class Aggregator:
    cfg: OTAConfig
    d: int
    m: int                                   # number of OTA devices
    projector: Any = None                    # analog only
    k: int = 0                               # analog sparsity level
    p_sched: Any = None                      # (T,) float32 jnp array
    q_sched: Any = None                      # (T,) int32 jnp array (digital)
    q_max: int = 0                           # static top_k bound (digital)

    # ------------------------------------------------------------------ state
    def init_delta(self) -> jnp.ndarray:
        """Per-device error accumulator Delta_m(0) = 0 (paper Alg. 1)."""
        return jnp.zeros((self.d,), jnp.dtype(self.cfg.state_dtype))

    # ----------------------------------------------------------------- encode
    def encode(self, g: jnp.ndarray, delta: jnp.ndarray, step, key,
               p_factor=1.0
               ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Per-device compression + frame construction. g: (d,) float32.

        p_factor scales this device's usable received power (1.0 on the
        AWGN MAC; h_m^2 under truncated-inversion fading, 0 in a deep fade).
        """
        cfg = self.cfg
        scheme = cfg.scheme
        g = g.astype(jnp.float32)
        if scheme == "ideal":
            return g, delta, {}
        p_t = self.p_sched[jnp.minimum(step, self.p_sched.shape[0] - 1)]
        p_t = p_t * jnp.asarray(p_factor, jnp.float32)
        if scheme == "a_dsgd":
            g_ec = g + delta.astype(jnp.float32)
            if isinstance(self.projector, DenseProjector):
                g_sp = compression.top_k_sparsify(g_ec, self.k)
                new_delta = g_ec - g_sp
            else:
                tau = compression.sampled_topk_threshold(g_ec, self.k, key)
                g_sp, new_delta = ops.ef_sparsify(
                    g, delta.astype(jnp.float32), tau,
                    use_kernel=cfg.use_kernel)
            g_tilde = self.projector.project(g_sp)
            use_mr = (jnp.asarray(step) < cfg.mean_removal_steps)
            frame, alpha = channel.make_frame(g_tilde, p_t, use_mr)
            metrics = {"alpha": alpha, "p_t": p_t,
                       "frame_power": channel.frame_power(frame)}
            return frame, new_delta.astype(delta.dtype), metrics
        # digital schemes
        q_t = self.q_sched[jnp.minimum(step, self.q_sched.shape[0] - 1)]
        if scheme == "d_dsgd":
            g_ec = g + delta.astype(jnp.float32)
            v_q = compression.sbc_quantize(g_ec, q_t, self.q_max)
            new_delta = g_ec - v_q
            return v_q, new_delta.astype(delta.dtype), {"q_t": q_t, "p_t": p_t}
        if scheme == "signsgd":
            v_q = compression.signsgd_compress(g, q_t, self.q_max)
            return v_q, delta, {"q_t": q_t, "p_t": p_t}
        if scheme == "qsgd":
            v_q = compression.qsgd_compress(g, q_t, self.q_max,
                                            cfg.quant_bits, key)
            return v_q, delta, {"q_t": q_t, "p_t": p_t}
        raise ValueError(f"unknown scheme {scheme!r}")

    # ----------------------------------------------------------------- decode
    def decode(self, y: jnp.ndarray, step) -> jnp.ndarray:
        """PS-side reconstruction of the average gradient from the MAC output."""
        cfg = self.cfg
        if cfg.scheme == "ideal" or cfg.scheme in DIGITAL_SCHEMES:
            return y / self.m
        use_mr = (jnp.asarray(step) < cfg.mean_removal_steps)
        y_body = channel.ps_normalize(y, use_mr)
        return amp_decode(y_body, self.projector, cfg.amp_iters)

    # ------------------------------------------------------------ sim driver
    def round_simulated(self, grads: jnp.ndarray, deltas: jnp.ndarray, step,
                        key: jnp.ndarray):
        """grads/deltas: (M, d). Returns (ghat, new_deltas, metrics)."""
        m = grads.shape[0]
        cfg = self.cfg
        dev_keys = jax.random.split(jax.random.fold_in(key, 1), m)
        analog = cfg.scheme in ANALOG_SCHEMES
        if analog and cfg.fading == "rayleigh":
            h = channel.rayleigh_gains(jax.random.fold_in(key, 2), m)
            p_fac, active = channel.truncated_inversion_power(
                h, cfg.fading_threshold)
        else:
            p_fac = jnp.ones((m,))
            active = jnp.ones((m,), bool)
        frames, new_deltas, metrics = jax.vmap(
            lambda g, dl, kk, pf: self.encode(g, dl, step, kk, pf))(
                grads, deltas, dev_keys, p_fac)
        if analog:
            frames = frames * active[:, None]
            if cfg.scheme != "ideal" and cfg.fading != "none":
                # a silent (deep-fade) device accumulates its whole update
                new_deltas = jnp.where(active[:, None], new_deltas,
                                       (grads + deltas).astype(new_deltas.dtype))
            y = channel.mac_sum(frames, jax.random.fold_in(key, 0),
                                cfg.sigma2)
        else:
            y = jnp.sum(frames, axis=0)
        ghat = self.decode(y, step)
        metrics = {k: jnp.mean(v) for k, v in metrics.items()}
        metrics["active_frac"] = jnp.mean(active.astype(jnp.float32))
        return ghat, new_deltas, metrics

    # ----------------------------------------------------- distributed driver
    def round_sharded(self, g_local: jnp.ndarray, delta_local: jnp.ndarray,
                      step, key: jnp.ndarray,
                      axis_names: Sequence[str],
                      groups: Optional[Sequence[Sequence[int]]] = None,
                      pre_average_groups: Optional[Sequence[Sequence[int]]] = None):
        """One aggregation round inside a shard_map (manual axes = devices).

        ``pre_average_groups``: optional axis_index_groups for the *ideal*
        intra-site average (hierarchical edge-site mapping); the MAC psum then
        runs over all manual devices and is divided by the group size.
        """
        axis_names = tuple(axis_names)
        group_size = 1
        if pre_average_groups is not None:
            group_size = len(pre_average_groups[0])
            g_local = jax.lax.psum(g_local, axis_names[-1],
                                   axis_index_groups=pre_average_groups)
            g_local = g_local / group_size
        frame, new_delta, metrics = self.encode(g_local, delta_local, step, key)
        y = frame
        for ax in axis_names:
            y = jax.lax.psum(y, ax)
        if group_size > 1:
            y = y / group_size       # identical frames within a site
        if self.cfg.scheme in ANALOG_SCHEMES:
            y = y + channel.awgn(key, y.shape, self.cfg.sigma2, y.dtype)
        ghat = self.decode(y, step)
        return ghat, new_delta, metrics


def make_aggregator(cfg: OTAConfig, d: int, m: int) -> Aggregator:
    """Build an Aggregator: precompute projector + power/bit schedules."""
    p_np = power.schedule_array(cfg.total_steps, cfg.p_avg, cfg.power_schedule)
    p_sched = jnp.asarray(p_np, jnp.float32)
    projector = None
    k = 0
    q_sched = None
    q_max = 0
    if cfg.scheme == "a_dsgd":
        projector = make_projector(cfg, d)
        if isinstance(projector, DenseProjector):
            k = cfg.k_for(d)
        else:
            # blocked: k scales with the realised channel dimension
            k = max(1, int(cfg.k_frac * projector.out_dim))
    elif cfg.scheme in DIGITAL_SCHEMES:
        s = cfg.s_for(d)
        q_cap = min(d // 2, 1 << 16)
        q_np = compression.digital_q_schedule(
            d, s, m, p_np, cfg.sigma2, scheme=cfg.scheme, l_q=cfg.quant_bits,
            q_cap=q_cap)
        q_sched = jnp.asarray(q_np, jnp.int32)
        q_max = int(max(int(q_np.max()), 1))
    return Aggregator(cfg=cfg, d=d, m=m, projector=projector, k=k,
                      p_sched=p_sched, q_sched=q_sched, q_max=q_max)
