"""Convergence-analysis quantities of the paper (§V) — evaluated numerically.

Implements lambda, sigma_max, rho(delta) (Lemma 2, chi-square quantile),
v(t) (Lemma 4, eq. 37b), its closed-form sum for P_t = P (eq. 42), and the
Theorem-1 bound on Pr{E_T}.  Host-side numpy: these feed tests and the
``benchmarks/convergence_bound.py`` harness, not the training loop.
"""
from __future__ import annotations

import math



def lambda_val(d: int, k: int) -> float:
    """lambda = sqrt((d - k)/d) (Corollary 1)."""
    return math.sqrt((d - k) / d)


def sigma_max(d: int, s_tilde: int) -> float:
    """Asymptotic largest singular value of A: sqrt(d/s_tilde) + 1 (App. A)."""
    return math.sqrt(d / s_tilde) + 1.0


def _gammainc_lower_reg(a: float, x: float) -> float:
    """Regularized lower incomplete gamma P(a, x) (series + continued frac)."""
    if x < 0 or a <= 0:
        raise ValueError
    if x == 0:
        return 0.0
    if x < a + 1.0:
        # series
        term = 1.0 / a
        total = term
        n = a
        for _ in range(10000):
            n += 1.0
            term *= x / n
            total += term
            if abs(term) < abs(total) * 1e-14:
                break
        return total * math.exp(-x + a * math.log(x) - math.lgamma(a))
    # continued fraction for Q(a,x), P = 1 - Q
    tiny = 1e-300
    b = x + 1.0 - a
    c = 1.0 / tiny
    dd = 1.0 / b
    h = dd
    for i in range(1, 10000):
        an = -i * (i - a)
        b += 2.0
        dd = an * dd + b
        if abs(dd) < tiny:
            dd = tiny
        c = b + an / c
        if abs(c) < tiny:
            c = tiny
        dd = 1.0 / dd
        delta = dd * c
        h *= delta
        if abs(delta - 1.0) < 1e-14:
            break
    q = math.exp(-x + a * math.log(x) - math.lgamma(a)) * h
    return 1.0 - q


def chi2_quantile(df: int, p: float) -> float:
    """x with P(df/2, x/2) = p, by bisection."""
    lo, hi = 0.0, max(10.0 * df, 100.0)
    while _gammainc_lower_reg(df / 2.0, hi / 2.0) < p:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _gammainc_lower_reg(df / 2.0, mid / 2.0) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def rho(delta: float, d: int) -> float:
    """Lemma 2: Pr{||u|| >= sigma_u rho(delta)} = delta for u ~ N(0, I_d)."""
    return math.sqrt(chi2_quantile(d, 1.0 - delta))


def v_t(t: int, *, d: int, k: int, s_tilde: int, m: int, p_t: float,
        sigma: float, g_bound: float, delta_prob: float = 1e-3) -> float:
    """Per-step perturbation bound v(t) (Lemma 4, eq. 37b)."""
    lam = lambda_val(d, k)
    smax = sigma_max(d, s_tilde)
    rr = rho(delta_prob, d)
    geo = (1.0 - lam ** (t + 1)) / (1.0 - lam)
    term1 = lam * ((1.0 + lam) * (1.0 - lam ** t) / (1.0 - lam) + 1.0) * g_bound
    term2 = rr * sigma / (m * math.sqrt(p_t)) * (smax * geo * g_bound + 1.0)
    return term1 + term2


def sum_v_constant_power(T: int, *, d: int, k: int, s_tilde: int, m: int,
                         p_avg: float, sigma: float, g_bound: float,
                         delta_prob: float = 1e-3) -> float:
    """Closed form of sum_{t=0}^{T-1} v(t) for P_t = P-bar (paper eq. 42).

    Note: the paper's printed (42) carries (1 - lam^{T+1}) in the second
    correction term; summing its own v(t) (eq. 37b) exactly gives
    lam (1 - lam^T) — we use the self-consistent form (difference < 1%, and
    vanishing in T).  Recorded in EXPERIMENTS.md as a suspected typo.
    """
    lam = lambda_val(d, k)
    smax = sigma_max(d, s_tilde)
    rr = rho(delta_prob, d)
    a = (2.0 * lam * g_bound / (1.0 - lam)
         + rr * sigma / (m * math.sqrt(p_avg)) * (smax * g_bound / (1.0 - lam) + 1.0))
    b = (lam * (1.0 + lam) * (1.0 - lam ** T) * g_bound / (1.0 - lam) ** 2
         + rr * sigma * smax * lam * (1.0 - lam ** T) * g_bound
         / (m * math.sqrt(p_avg) * (1.0 - lam) ** 2))
    return a * T - b


def eta_max(T: int, c_strong: float, eps: float, g_bound: float,
            sum_v: float) -> float:
    """Learning-rate ceiling of Theorem 1 (eq. 40)."""
    return 2.0 * (c_strong * eps * T - math.sqrt(eps) * sum_v) / (T * g_bound ** 2)


def theorem1_bound(T: int, *, eta: float, c_strong: float, eps: float,
                   g_bound: float, sum_v: float, theta_star_norm: float) -> float:
    """Pr{E_T} bound (eq. 41). Returns +inf when the denominator is <= 0."""
    denom_rate = 2.0 * eta * c_strong * eps - eta ** 2 * g_bound ** 2
    if denom_rate <= 0:
        return float("inf")
    lipschitz = 2.0 * math.sqrt(eps) / denom_rate
    denom = T - eta * lipschitz * sum_v
    if denom <= 0:
        return float("inf")
    return (eps / (denom_rate * denom)) * math.log(
        math.e * theta_star_norm ** 2 / eps)
