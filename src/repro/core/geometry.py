"""Geometry-grounded channel model: placement-derived large-scale gains.

The fading axis (:mod:`repro.core.fading`, docs/DESIGN.md §8) is purely
statistical — Rayleigh draws with no notion of *where* devices are.  The
deployable version of the paper's MAC derives per-device SNR from placement:
cell radius, carrier frequency, path-loss exponent, BS/user antenna gains
(the channel setup of LConann's ``fl_main.py``, SNIPPETS.md §1).  This
module adds that layer as a *large-scale* gain composed multiplicatively
onto the small-scale fading draw (docs/DESIGN.md §12):

    p_factor_m  =  small_scale_m  *  g_m,
    g_m         =  G_bs * G_user * (d_m / d0) ** (-gamma),

where ``d_m`` is device m's distance to the BS (devices drawn uniformly on
a disk of radius ``cell_radius`` around a BS mast of height ``bs_height``)
and ``d0`` is the reference distance at which the normalised gain equals
the antenna gains alone.  The *normalised* power-law (rather than the
absolute Friis budget, which at 915 MHz and city-scale distances is ~1e-10
and would drown any trainable signal in fixed-σ² AWGN) keeps ``g_m`` in a
regime where sweeping ``cell_radius`` traces out the accuracy-vs-coverage
trade-off; :func:`link_budget_db` exposes the absolute dB budget for
diagnostics and radio-planning sanity checks.

Everything follows the :mod:`repro.core.fading` conventions:

* device positions are drawn *once per run* from the run-level
  :func:`geometry_base_key` — large-scale geometry is a property of the
  deployment, not of the per-round key stream, so a ``seed`` sweep axis
  holds placements fixed (common random numbers for paired comparisons);
* ``cell_radius`` and ``path_loss_exp`` enter as traced multiplies
  (``exp(-gamma * log(d/d0))``), so both are vmappable sweep axes
  (``SCALAR_VMAP_AXES`` in :mod:`repro.experiments.sweep`);
* the structural bits (``geometry`` kind, antenna gains, BS height,
  carrier frequency, reference distance) live on a frozen
  :class:`GeometrySpec` — static, one compiled program per combination.

With ``geometry="none"`` (the default) no op from this module enters any
traced program, so every pre-geometry golden stays byte-identical (the
static-gating contract shared with :mod:`repro.robust`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

#: recognised geometry kinds (validated by spec_from_cfg)
GEOMETRIES = ("none", "disk")

#: salt decorrelating the run-level placement stream from every other
#: consumer of OTAConfig.seed (fading streams, fault traces, projectors)
GEOMETRY_SEED_SALT = 0x6E00

#: speed of light, for the absolute (Friis) link budget
SPEED_OF_LIGHT = 299_792_458.0


@dataclass(frozen=True)
class GeometrySpec:
    """Static description of the cell geometry (trace-defining bits).

    The *values* of ``cell_radius`` / ``path_loss_exp`` live on the scheme
    object as traced scalars (swappable per grid point via
    ``Scheme.with_overrides``); this spec pins what stays constant across a
    sweep grid: the placement model, the antenna gains, the BS mast height,
    the carrier (diagnostics only — see :func:`link_budget_db`), and the
    normalisation distance ``ref_dist``.
    """

    kind: str = "disk"  # disk (uniform over the cell disk)
    carrier_freq: float = 915e6  # f_c in Hz (the LConann setup's 915 MHz)
    bs_gain_db: float = 5.0  # BS antenna gain (dBi)
    user_gain_db: float = 0.0  # device antenna gain (dBi)
    bs_height: float = 10.0  # BS mast height (m)
    ref_dist: float = 100.0  # d0: gain = antenna gains alone at d0 (m)


def spec_from_cfg(cfg) -> GeometrySpec:
    """Build the spec from an OTAConfig, validating the kind."""
    if cfg.geometry not in GEOMETRIES:
        raise ValueError(
            f"unknown geometry {cfg.geometry!r}; known: {GEOMETRIES}"
        )
    return GeometrySpec(
        kind=cfg.geometry if cfg.geometry != "none" else "disk",
        carrier_freq=cfg.carrier_freq,
        bs_gain_db=cfg.bs_gain_db,
        user_gain_db=cfg.user_gain_db,
        bs_height=cfg.bs_height,
        ref_dist=cfg.geo_ref_dist,
    )


def geometry_base_key(seed: int) -> jnp.ndarray:
    """Run-level key anchoring the device placement.

    Derived from ``OTAConfig.seed`` like :func:`fading.fading_base_key` —
    the deployment is a property of the run configuration, so a ``seed``
    sweep axis (which shifts the round keys) compares schedulers and power
    budgets over the *same* placement.
    """
    return jax.random.PRNGKey(seed ^ GEOMETRY_SEED_SALT)


def unit_positions(key: jnp.ndarray, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(r, theta) of m devices uniform on the unit disk.

    ``r = sqrt(U)`` gives the area-uniform radial law; scaling by a traced
    ``cell_radius`` outside this function keeps the radius a data-like
    sweep axis (the draw itself is radius-independent).
    """
    u, v = jax.random.uniform(key, (2, m))
    return jnp.sqrt(u), 2.0 * jnp.pi * v


def device_distances(key: jnp.ndarray, m: int, cell_radius, spec: GeometrySpec):
    """(m,) 3-D device→BS distances for a disk cell of traced radius.

    The BS sits at height ``spec.bs_height`` over the cell centre, so the
    distance floors at the mast height — no device is ever at d = 0, and
    the power law below needs no singularity guard for physical configs.
    """
    r_unit, _theta = unit_positions(key, m)
    horiz = jnp.asarray(cell_radius, jnp.float32) * r_unit
    return jnp.sqrt(horiz * horiz + jnp.float32(spec.bs_height) ** 2)


def large_scale_gains(
    key: jnp.ndarray, m: int, cell_radius, path_loss_exp, spec: GeometrySpec
) -> jnp.ndarray:
    """(m,) normalised large-scale power gains ``g_m`` (pure in the key).

    ``g_m = G_ant * (d_m / d0) ** (-gamma)`` with ``G_ant`` the combined
    antenna gains (linear) and ``gamma`` the traced path-loss exponent —
    realised as ``exp(-gamma * log(d/d0))`` so the exponent is a traced
    multiply and rides a vmapped sweep axis.  ``d0 = spec.ref_dist``
    normalises: a device at the reference distance sees the antenna gains
    alone, devices inside it see a (bounded) boost, devices outside lose
    power polynomially — which is what makes accuracy monotone in
    ``cell_radius`` (benchmarks/fig13_geometry.py gates this).
    """
    d = device_distances(key, m, cell_radius, spec)
    g_ant = jnp.float32(10.0 ** ((spec.bs_gain_db + spec.user_gain_db) / 10.0))
    ratio = jnp.maximum(d / jnp.float32(spec.ref_dist), 1e-6)
    gamma = jnp.asarray(path_loss_exp, jnp.float32)
    return g_ant * jnp.exp(-gamma * jnp.log(ratio))


def fspl_db(dist_m, carrier_freq) -> jnp.ndarray:
    """Free-space path loss in dB: ``20 log10(4 pi d f / c)`` (Friis)."""
    d = jnp.maximum(jnp.asarray(dist_m, jnp.float32), 1e-3)
    f = jnp.float32(carrier_freq)
    return 20.0 * jnp.log10(4.0 * jnp.pi * d * f / SPEED_OF_LIGHT)


def link_budget_db(dist_m, path_loss_exp, spec: GeometrySpec) -> jnp.ndarray:
    """Absolute received-power budget (dB, relative to transmit power).

    Friis free-space loss up to ``spec.ref_dist`` at ``spec.carrier_freq``,
    then the ``path_loss_exp`` power law beyond it — the standard
    log-distance model radio planners use.  Diagnostics only: the
    simulation gain (:func:`large_scale_gains`) is the *normalised* power
    law, because composing the absolute budget (~ -90 dB at city scale)
    with the paper's fixed-σ² MAC would leave nothing trainable to sweep.
    """
    d = jnp.maximum(jnp.asarray(dist_m, jnp.float32), 1e-3)
    gamma = jnp.asarray(path_loss_exp, jnp.float32)
    ref = jnp.float32(spec.ref_dist)
    loss = fspl_db(ref, spec.carrier_freq) + 10.0 * gamma * jnp.log10(
        jnp.maximum(d / ref, 1.0)
    )
    return jnp.float32(spec.bs_gain_db + spec.user_gain_db) - loss
