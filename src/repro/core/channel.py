"""The Gaussian MAC and the analog frame layout (paper §II, §IV, §IV-A).

These primitives are consumed by the scheme classes and generic drivers in
:mod:`repro.core.schemes`: analog schemes build frames with
:func:`make_frame`, the simulated driver superposes them with
:func:`mac_sum`, the sharded drivers draw their AWGN from :func:`awgn`, and
the fading helpers at the bottom implement the ``a_dsgd_fading`` scheme's
truncated channel inversion.

Frame layout (static length = s_tilde + 2, covering both §IV variants):

    x_m = [ sqrt(a) * (g_tilde - mu * 1),  sqrt(a) * mu,  sqrt(a) ]

with mu = mean(g_tilde) when mean-removal is active (paper: the first ~20
iterations) and mu = 0 otherwise — in which case the layout degenerates to
the basic scheme of eq. (12)-(14) at the cost of one idle channel use, which
keeps the frame shape static under jit (the active/inactive switch is traced).

    alpha = P_t / (||g_tilde||^2 - (s_tilde - 1) * mu^2 + 1)      (eq. 22)
          = P_t / (||g_tilde||^2 + 1)            when mu = 0      (eq. 13)

PS-side normalisation (eq. 25 / eq. 18):

    y_body = (y[:s_tilde] + y[s_tilde] * 1) / y[s_tilde + 1]
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def make_frame(
    g_tilde: jnp.ndarray, p_t, use_mean_removal
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build the per-device channel frame. Returns (frame, alpha).

    use_mean_removal: traced bool/0-1 scalar.
    """
    s_tilde = g_tilde.shape[-1]
    use = jnp.asarray(use_mean_removal, g_tilde.dtype)
    mu = use * jnp.mean(g_tilde)
    energy = jnp.sum(g_tilde * g_tilde) - (s_tilde - 1) * mu * mu + 1.0
    alpha = jnp.asarray(p_t, g_tilde.dtype) / jnp.maximum(energy, 1e-12)
    ra = jnp.sqrt(alpha)
    frame = jnp.concatenate([ra * (g_tilde - mu), jnp.stack([ra * mu, ra])])
    return frame, alpha


def frame_power(frame: jnp.ndarray) -> jnp.ndarray:
    """||x_m||^2 — tests assert == P_t (paper eq. 12/21)."""
    return jnp.sum(frame * frame)


def awgn(key: jnp.ndarray, shape, sigma2: float, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.sqrt(jnp.asarray(sigma2, dtype)) * jax.random.normal(key, shape, dtype)


def mac_sum(frames: jnp.ndarray, key: jnp.ndarray, sigma2: float) -> jnp.ndarray:
    """Simulation path: y = sum_m x_m + z  over a leading device axis."""
    y = jnp.sum(frames, axis=0)
    return y + awgn(key, y.shape, sigma2, y.dtype)


def site_awgn(
    key: jnp.ndarray,
    shape,
    sigma2,
    n_sites: int,
    site_noise_scale=1.0,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Summed receiver noise of a hierarchical MAC (n_sites edge sites).

    Each site observes its own OTA partial sum plus AWGN of variance
    ``sigma2 * site_noise_scale`` (keyed ``fold_in(key, site)``); combining
    the forwarded partials at the PS adds the site noises, so the
    effective MAC noise grows linearly in n_sites — the modeled price of
    hierarchy (repro.population.hierarchy).  Both scalars may be traced.
    """
    sig = jnp.asarray(sigma2, dtype) * jnp.asarray(site_noise_scale, dtype)
    sites = jnp.arange(n_sites)
    z = jax.vmap(lambda j: awgn(jax.random.fold_in(key, j), shape, sig, dtype))(sites)
    return jnp.sum(z, axis=0)


#: a received scale slot below this is indistinguishable from the unit-
#: variance AWGN — the PS then skips the rescale (scale 1.0) instead of
#: amplifying a noise reading (dividing by a tiny/negative slot would blow
#: up / sign-flip the whole observation; the clean slot sum_m sqrt(alpha_m)
#: is positive and far above this for any sane power budget)
SCALE_SLOT_FLOOR = 1e-3


def ps_normalize(y: jnp.ndarray, use_mean_removal) -> jnp.ndarray:
    """Recover the PS observation body (eq. 18 / eq. 25).

    The clean scale slot is ``sum_m sqrt(alpha_m) > 0`` by construction;
    noise-dominated readings (<= SCALE_SLOT_FLOOR, possible at very low
    P-bar) fall back to scale 1.0 — bounded magnitude, never a sign flip
    (AMP is equivariant to the *positive* scale, so alignment survives).
    """
    body, mu_slot, scale_slot = y[:-2], y[-2], y[-1]
    use = jnp.asarray(use_mean_removal, y.dtype)
    scale = jnp.where(scale_slot > SCALE_SLOT_FLOOR, scale_slot, 1.0)
    return (body + use * mu_slot) / scale


# ---------------------------------------------------------------------------
# fading MAC (beyond-paper: the §II extension realised in the follow-up [34])
# ---------------------------------------------------------------------------


def rayleigh_gains(key: jnp.ndarray, m: int) -> jnp.ndarray:
    """|h_m| for a flat Rayleigh-fading block: |CN(0,1)| magnitudes."""
    re, im = jax.random.normal(key, (2, m)) / jnp.sqrt(2.0)
    return jnp.sqrt(re * re + im * im)


def truncated_inversion_power(h: jnp.ndarray, threshold: float = 0.3):
    """Truncated channel inversion (follow-up [34] §III).

    Devices with |h_m| below the truncation threshold stay silent this
    round (inverting a deep fade would blow the power budget); the rest
    pre-scale by 1/h_m so their signals superpose coherently at the PS.
    Inversion costs transmit power: under the per-round constraint
    ||x_m||^2 <= P_t the usable *received* power becomes P_t * h_m^2.
    Returns (received-power factor h^2 * active, participation mask) —
    the frame math is then the AWGN pipeline with a per-device P_t scale,
    and the y_s scale slot absorbs the resulting alpha_m spread (eq. 18).
    """
    active = h >= threshold
    return jnp.where(active, h * h, 0.0), active
