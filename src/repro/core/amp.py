"""Approximate message passing (AMP) reconstruction at the PS (paper §IV, [31]).

Soft-threshold AMP for y = A x + z with x ~ k-sparse:

    r_t   = x_t + A^T z_t
    x_t+1 = soft(r_t, tau_t),   tau_t = mult * ||z_t|| / sqrt(s)
    z_t+1 = y - A x_t+1 + z_t * (||x_t+1||_0 / s)      (Onsager correction)

Lemma 1 of the paper: the effective observation becomes x + sigma_tau * w with
sigma_tau decreasing monotonically — the tests verify this contraction on
synthetic k-sparse signals.

The blocked variant runs an independent AMP per projection block (the
block-diagonal A factorises the problem) — fully batched, shardable along d.
:func:`amp_blocked_core` is the single chunked implementation behind every
blocked decode: the on-the-fly A chunk is generated exactly ONCE per decode
(vs 2*iters+1 times for launch-per-op decoding) and consumed by all
iterations, either as a jnp ``lax.scan`` (XLA path) or inside the fused
single-launch Pallas kernel (kernels/amp_fused.py, ``use_kernel=True``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def soft_threshold(x: jnp.ndarray, tau) -> jnp.ndarray:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


def _debias_factor(num, den):
    """Clamped LS rescale factor correcting the soft-threshold shrinkage.

    Shrinkage can only make ||A x|| smaller than its LS fit to y, so the
    correction is >= 1 by construction; raw factors < 1 (converged AMP — the
    Onsager term has already debiased) or >> 1 (den -> 0 at very low SNR)
    are noise fits and are clamped away.
    """
    return jnp.clip(num / jnp.maximum(den, 1e-12), 1.0, 2.0)


def _ls_rescale(x: jnp.ndarray, ax: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Debias the soft-threshold shrinkage: scale x so A x best matches y."""
    return x * _debias_factor(jnp.vdot(ax, y), jnp.vdot(ax, ax))


def amp_decode_dense(y: jnp.ndarray, A: jnp.ndarray, iters: int = 20,
                     threshold_mult: float = 1.3,
                     debias: bool = True) -> jnp.ndarray:
    """Recover x (d,) from y (s,) with the dense measurement matrix A (s,d)."""
    s, d = A.shape

    def body(_, carry):
        x, z = carry
        sigma_hat = jnp.linalg.norm(z) / jnp.sqrt(s)
        r = x + A.T @ z
        x_new = soft_threshold(r, threshold_mult * sigma_hat)
        onsager = z * (jnp.sum(x_new != 0.0) / s)
        z_new = y - A @ x_new + onsager
        return x_new, z_new

    x0 = jnp.zeros((d,), y.dtype)
    x, _ = jax.lax.fori_loop(0, iters, body, (x0, y))
    if debias:
        x = _ls_rescale(x, A @ x, y)
    return x


def amp_decode_blocked(yb: jnp.ndarray, projector, iters: int = 20,
                       threshold_mult: float = 1.3,
                       debias: bool = True) -> jnp.ndarray:
    """Per-block AMP. yb: (n_blocks, s_block) -> flat (d,) estimate.

    All matvecs go through the projector (on-the-fly A), so each AMP
    iteration is two batched projection applications + pointwise math, and
    every application regenerates A — 2*iters+1 generations per decode.
    Prefer :func:`amp_blocked_core` (one generation per decode) unless the
    whole A fits the working-set budget anyway.
    """
    n_blocks, s_block = yb.shape
    c = projector.block_size

    def body(_, carry):
        xb, zb = carry
        sigma_hat = jnp.linalg.norm(zb, axis=1, keepdims=True) / jnp.sqrt(
            jnp.float32(s_block))
        rb = xb + projector.project_t_blocks(zb)
        xb_new = soft_threshold(rb, threshold_mult * sigma_hat)
        onsager = zb * (jnp.sum(xb_new != 0.0, axis=1, keepdims=True) / s_block)
        zb_new = yb - projector.project_blocks(xb_new) + onsager
        return xb_new, zb_new

    x0 = jnp.zeros((n_blocks, c), yb.dtype)
    xb, _ = jax.lax.fori_loop(0, iters, body, (x0, yb))
    if debias:
        axb = projector.project_blocks(xb)
        num = jnp.sum(axb * yb, axis=1, keepdims=True)
        den = jnp.sum(axb * axb, axis=1, keepdims=True)
        xb = xb * _debias_factor(num, den)
    return projector.from_blocks(xb)


def amp_blocked_core(yb: jnp.ndarray, seed, c: int, iters: int = 20,
                     chunk_blocks: int = 8, threshold_mult: float = 1.3,
                     debias: bool = True, rademacher: bool = True,
                     id_offset=0, use_kernel: bool = False) -> jnp.ndarray:
    """Chunked per-block AMP with ONE A-generation per block per decode.

    yb: (n_blocks, s_block) -> xb: (n_blocks, c).  ``seed`` and
    ``id_offset`` (global index of this slice's first block — lets a device
    decode a sub-range of blocks with the encoder's global block ids) may
    be traced uint32 scalars.

    ``use_kernel=False``: jnp ``lax.scan`` over chunks of ``chunk_blocks``
    blocks; each chunk's A is generated once and all AMP iterations for its
    blocks run against it inside the scan body (blocks are independent
    sub-problems under the block-diagonal A), bounding the A working set.
    ``use_kernel=True``: the same structure realised in VMEM by the fused
    single-launch Pallas kernel (kernels/amp_fused.py).
    """
    if use_kernel:
        from repro.kernels import ops
        return ops.amp_decode_fused(yb, seed=seed, c=c, iters=iters,
                                    threshold_mult=threshold_mult,
                                    debias=debias, rademacher=rademacher,
                                    nb_tile=chunk_blocks,
                                    id_offset=id_offset)
    from repro.kernels import ref
    n_blocks, s_block = yb.shape
    ni = min(chunk_blocks, n_blocks)
    pad = (-n_blocks) % ni
    yb_p = jnp.pad(yb, ((0, pad), (0, 0)))
    n_outer = (n_blocks + pad) // ni
    ys = yb_p.reshape(n_outer, ni, s_block)
    ids = (jnp.arange(n_outer * ni, dtype=jnp.uint32)
           + jnp.asarray(id_offset, jnp.uint32)).reshape(n_outer, ni)

    def gen(b):
        return ref.block_matrix_ref(seed, b, s_block, c, rademacher)

    def chunk_amp(_, inp):
        ids_c, y_c = inp
        A = jax.vmap(gen)(ids_c)                     # (ni, s, c) — ONCE

        def body(_, carry):
            x, z = carry
            sigma_hat = jnp.linalg.norm(z, axis=1, keepdims=True) / jnp.sqrt(
                jnp.float32(s_block))
            r = x + jnp.einsum("isc,is->ic", A, z)
            x_new = soft_threshold(r, threshold_mult * sigma_hat)
            onsager = z * (jnp.sum(x_new != 0.0, axis=1, keepdims=True)
                           / s_block)
            z_new = y_c - jnp.einsum("isc,ic->is", A, x_new) + onsager
            return x_new, z_new

        x0 = jnp.zeros((ni, c), y_c.dtype)
        x, _ = jax.lax.fori_loop(0, iters, body, (x0, y_c))
        if debias:
            ax = jnp.einsum("isc,ic->is", A, x)
            num = jnp.sum(ax * y_c, axis=1, keepdims=True)
            den = jnp.sum(ax * ax, axis=1, keepdims=True)
            x = x * _debias_factor(num, den)
        return None, x

    _, xs = jax.lax.scan(chunk_amp, None, (ids, ys))
    return xs.reshape(-1, c)[:n_blocks]


def amp_decode_blocked_scan(yb: jnp.ndarray, projector, iters: int = 20,
                            threshold_mult: float = 1.3,
                            debias: bool = True) -> jnp.ndarray:
    """Chunked-scan AMP sized from a :class:`BlockedProjector` (the jnp
    analogue of the fused kernel; see :func:`amp_blocked_core`)."""
    xb = amp_blocked_core(yb, projector.seed, projector.block_size, iters,
                          projector.chunk_blocks, threshold_mult, debias,
                          projector.rademacher)
    return projector.from_blocks(xb)


def amp_decode(y_flat: jnp.ndarray, projector, iters: int = 20,
               threshold_mult: float = 1.3) -> jnp.ndarray:
    """Dispatch on projector type; y_flat has projector.out_dim entries."""
    from repro.core.projection import BlockedProjector, DenseProjector
    if isinstance(projector, DenseProjector):
        return amp_decode_dense(y_flat, projector.matrix(), iters,
                                threshold_mult)
    assert isinstance(projector, BlockedProjector)
    yb = y_flat.reshape(projector.n_blocks, projector.s_block)
    if projector.use_kernel:
        xb = amp_blocked_core(yb, projector.seed, projector.block_size,
                              iters, projector.kernel_nb_tile,
                              threshold_mult, rademacher=projector.rademacher,
                              use_kernel=True)
        return projector.from_blocks(xb)
    if projector.n_blocks > projector.chunk_blocks:
        return amp_decode_blocked_scan(yb, projector, iters, threshold_mult)
    return amp_decode_blocked(yb, projector, iters, threshold_mult)
