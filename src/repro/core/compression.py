"""Gradient compression primitives (paper §III, §IV and the §VI baselines).

All functions are pure and jit-friendly.  Top-k selection comes in two
flavours: exact (lax.top_k — paper-scale) and sampled-quantile threshold
(framework-scale, one pass + pointwise mask; see docs/DESIGN.md §4.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# sparsification
# ---------------------------------------------------------------------------


def top_k_sparsify(v: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact sp_k: keep the k largest-magnitude entries of v (paper Alg. 1)."""
    d = v.shape[-1]
    k = min(k, d)
    mag = jnp.abs(v)
    kth = jax.lax.top_k(mag, k)[0][..., -1:]
    keep = mag >= kth
    # guard against ties inflating the support: exact k not required by the
    # algorithm (ties share the same magnitude), but tests check <= k + ties.
    return jnp.where(keep, v, 0.0)


def topk_threshold(v: jnp.ndarray, k: int) -> jnp.ndarray:
    """The k-th largest |v| (exact)."""
    return jax.lax.top_k(jnp.abs(v), min(k, v.shape[-1]))[0][..., -1]


def sampled_topk_threshold(v: jnp.ndarray, k: int, key: jnp.ndarray,
                           n_samples: int = 1 << 16) -> jnp.ndarray:
    """Approximate k-th largest |v| from a strided sample (framework scale).

    Strided sampling (start offset from the key) instead of random gather:
    indices stay int32-safe at d > 2^31 and the read is a cheap slice.  The
    sparsifier then applies the threshold pointwise.
    """
    d = v.shape[-1]
    n = min(n_samples, d)
    stride = d // n
    if stride <= 1:
        sample = jnp.abs(v)
    else:
        sample = jnp.abs(jax.lax.slice_in_dim(v, 0, n * stride, stride,
                                              axis=-1))
    q = 1.0 - (k / d)
    return jnp.quantile(sample, q, axis=-1)


def error_feedback(g: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """g^ec = g + Delta (paper Alg. 1 line 5)."""
    return g + delta


def residual(g_ec: jnp.ndarray, g_sp: jnp.ndarray) -> jnp.ndarray:
    """Delta' = g^ec - g^sp (paper eq. 10)."""
    return g_ec - g_sp


# ---------------------------------------------------------------------------
# D-DSGD quantizer (paper §III, following Sattler et al. [21])
# ---------------------------------------------------------------------------


def sbc_quantize(v: jnp.ndarray, q_t: jnp.ndarray, q_max: int) -> jnp.ndarray:
    """Sparse binary compression with a dynamic budget q_t <= q_max.

    Keep the q_t largest and q_t smallest entries (by value); compute the
    mean of surviving positives (mu+) and negatives (mu-); the side with the
    larger |mean| wins — its entries are set to that mean, the other side is
    zeroed (paper §III).  q_t may be traced (per-step bit budget); q_max is
    the static bound used for top_k.
    """
    assert v.ndim == 1, "sbc_quantize is per-device; vmap for batches"
    d = v.shape[-1]
    q_max = min(q_max, d)
    top_vals, _ = jax.lax.top_k(v, q_max)          # descending
    bot_vals, _ = jax.lax.top_k(-v, q_max)         # descending of -v
    qi = jnp.clip(jnp.asarray(q_t, jnp.int32) - 1, 0, q_max - 1)
    # dynamic thresholds: q_t-th largest / q_t-th smallest
    hi_thresh = top_vals[qi]
    lo_thresh = -bot_vals[qi]
    pos_keep = (v >= hi_thresh) & (v > 0)
    neg_keep = (v <= lo_thresh) & (v < 0)
    npos = jnp.maximum(pos_keep.sum(-1), 1)
    nneg = jnp.maximum(neg_keep.sum(-1), 1)
    mu_pos = jnp.where(pos_keep, v, 0.0).sum(-1) / npos
    mu_neg = jnp.where(neg_keep, v, 0.0).sum(-1) / nneg
    pos_wins = mu_pos > jnp.abs(mu_neg)
    out = jnp.where(pos_wins,
                    jnp.where(pos_keep, mu_pos, 0.0),
                    jnp.where(neg_keep, mu_neg, 0.0))
    return jnp.where(jnp.asarray(q_t) > 0, out, jnp.zeros_like(out))


# ---------------------------------------------------------------------------
# digital baselines (paper §VI): SignSGD [16] and QSGD [2] under a bit budget
# ---------------------------------------------------------------------------


def signsgd_compress(v: jnp.ndarray, q_t: jnp.ndarray, q_max: int) -> jnp.ndarray:
    """Top-q_t by magnitude, transmit signs (eq. 43)."""
    assert v.ndim == 1
    d = v.shape[-1]
    q_max = min(q_max, d)
    mags, _ = jax.lax.top_k(jnp.abs(v), q_max)
    qi = jnp.clip(jnp.asarray(q_t, jnp.int32) - 1, 0, q_max - 1)
    tau = mags[qi]
    keep = jnp.abs(v) >= tau
    return jnp.where(keep & (jnp.asarray(q_t) > 0), jnp.sign(v), 0.0)


def qsgd_compress(v: jnp.ndarray, q_t: jnp.ndarray, q_max: int,
                  bits: int, key: jnp.ndarray) -> jnp.ndarray:
    """Top-q_t entries quantized with QSGD stochastic rounding (eq. 44).

    QSGD: q(v_i) = ||v_sel|| * sign(v_i) * xi_i,  xi in {0, 1/L, ..., 1},
    L = 2^bits levels, stochastic rounding unbiased.
    """
    assert v.ndim == 1
    d = v.shape[-1]
    q_max = min(q_max, d)
    mags, _ = jax.lax.top_k(jnp.abs(v), q_max)
    qi = jnp.clip(jnp.asarray(q_t, jnp.int32) - 1, 0, q_max - 1)
    tau = mags[qi]
    keep = (jnp.abs(v) >= tau) & (jnp.asarray(q_t) > 0)
    v_sel = jnp.where(keep, v, 0.0)
    norm = jnp.linalg.norm(v_sel, axis=-1, keepdims=True)
    norm = jnp.maximum(norm, 1e-12)
    L = float(2 ** bits)
    scaled = jnp.abs(v_sel) / norm * L
    floor = jnp.floor(scaled)
    prob = scaled - floor
    u = jax.random.uniform(key, v.shape)
    level = floor + (u < prob)
    return jnp.sign(v_sel) * level / L * norm


# ---------------------------------------------------------------------------
# bit accounting (host-side, numpy)
# ---------------------------------------------------------------------------


def _log2_binom_np(d: int, q: np.ndarray) -> np.ndarray:
    from math import lgamma
    q = np.asarray(q, np.float64)
    out = np.zeros_like(q)
    ln2 = np.log(2.0)
    for i, qq in np.ndenumerate(q):
        qq = float(qq)
        if qq <= 0 or qq >= d:
            out[i] = 0.0
        else:
            out[i] = (lgamma(d + 1) - lgamma(qq + 1) - lgamma(d - qq + 1)) / ln2
    return out


def mac_bit_budget(s: int, m: int, p_t: np.ndarray, sigma2: float) -> np.ndarray:
    """R_t = s/(2M) log2(1 + M P_t / (s sigma^2))  (paper eq. 8)."""
    p_t = np.asarray(p_t, np.float64)
    return s / (2.0 * m) * np.log2(1.0 + m * p_t / (s * sigma2))


def ddsgd_bits(d: int, q: np.ndarray) -> np.ndarray:
    """r_t = log2 C(d, q_t) + 33   (paper eq. 9)."""
    return _log2_binom_np(d, q) + 33.0


def signsgd_bits(d: int, q: np.ndarray) -> np.ndarray:
    """r_t = log2 C(d, q) + q   (paper eq. 43)."""
    return _log2_binom_np(d, q) + np.asarray(q, np.float64)


def qsgd_bits(d: int, q: np.ndarray, l_q: int) -> np.ndarray:
    """r_t = 32 + log2 C(d, q) + (1 + l_Q) q   (paper eq. 44)."""
    return 32.0 + _log2_binom_np(d, q) + (1.0 + l_q) * np.asarray(q, np.float64)


def max_q_for_budget(d: int, budget: float, bits_fn, q_cap: int | None = None) -> int:
    """Largest integer q with bits_fn(d, q) <= budget (paper: choose q_t)."""
    hi = min(d // 2, q_cap) if q_cap else d // 2
    lo = 0
    if bits_fn(d, np.asarray([1.0]))[0] > budget:
        return 0
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if bits_fn(d, np.asarray([float(mid)]))[0] <= budget:
            lo = mid
        else:
            hi = mid - 1
    return lo


def digital_q_schedule(d: int, s: int, m: int, p_ts: np.ndarray, sigma2: float,
                       scheme: str = "d_dsgd", l_q: int = 2,
                       q_cap: int | None = None) -> np.ndarray:
    """Host-precomputed q_t for every step of a digital scheme."""
    budgets = mac_bit_budget(s, m, p_ts, sigma2)
    try:
        fn = functools.partial(BIT_COSTS[scheme], l_q=l_q)
    except KeyError:
        raise ValueError(f"no bit-cost model for scheme {scheme!r}; known: "
                         f"{', '.join(sorted(BIT_COSTS))}") from None
    return np.asarray([max_q_for_budget(d, float(b), fn, q_cap) for b in budgets],
                      np.int32)


#: per-scheme bit-cost models r_t(q) used to size the q_t schedule; digital
#: Scheme subclasses (repro.core.schemes) are looked up here by their
#: registered name.
BIT_COSTS = {
    "d_dsgd": lambda d, q, l_q: ddsgd_bits(d, q),
    "ddsgd": lambda d, q, l_q: ddsgd_bits(d, q),
    "signsgd": lambda d, q, l_q: signsgd_bits(d, q),
    "qsgd": lambda d, q, l_q: qsgd_bits(d, q, l_q),
}
