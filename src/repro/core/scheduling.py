"""Subband scheduling: which devices transmit on which subband each round.

The band-limited coordinated-descent line of work (arXiv:2102.07972) splits
the bandwidth budget into ``n_subbands`` orthogonal subbands and lets a
*scheduler* pick, each round, the subset of devices that transmit — one
device per subband — instead of superposing everyone.  This module adds
that layer on top of the MAC drivers (docs/DESIGN.md §12):

* a :class:`Scheduler` is registered under a name
  (:func:`register_scheduler`) and resolved from an ``OTAConfig`` via
  :func:`get_scheduler` (``scheduler="none"`` resolves to ``None`` — no
  scheduling op enters the traced program, preserving every pre-scheduling
  golden byte-identically);
* :func:`schedule` turns a scheduler's per-device priorities into the
  round's transmit set as a **pure function of (key, t, gains, state)** —
  no hidden state, so compiled runs stay one ``jit(lax.scan)`` and the
  only carried piece is the proportional-fair average-rate vector, which
  rides the scan carry (banked beside the error-feedback state in the
  population engine);
* ``n_subbands`` enters as a traced compare (``rank < n_subbands``, the
  ``k_active`` pattern from repro.population), so subband-count grids ride
  one vmapped program (``SCALAR_VMAP_AXES`` in repro.experiments.sweep);
  the scheduler *kind* selects program structure and stays a static axis.

Unscheduled devices are treated exactly like deep-faded ones: their frames
never reach the MAC and their whole update banks into the error-feedback
state (``Scheme.silent_state``), so scheduling composes with every scheme
and fault model rather than special-casing any.

Schedulers:

``round_robin``  deterministic cycle: round t serves devices
                 ``(t*S + j) mod M``; gains-blind, maximally fair.
``gain_ranked``  picks the S devices with the largest received-power
                 factors this round (post-geometry, post-fading) — the
                 max-SNR policy; throughput-optimal, fairness-blind.
``prop_fair``    classic proportional fairness: priority is the ratio of
                 the instantaneous rate ``log1p(gain)`` to an
                 exponentially-averaged served rate, carried across
                 rounds with horizon ``pf_horizon`` — serves strong
                 channels *when they are unusually strong for that
                 device*, trading sum-rate for fairness.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

#: round-key salt for the scheduler draw (0 MAC AWGN, 1 encode, 2 channel,
#: 3 availability, 4 cohort sampling, 5 straggler latency, 6 fault trace)
SALT_SCHED = 7

SCHEDULER_REGISTRY: Dict[str, Type["Scheduler"]] = {}


def register_scheduler(name: str):
    """Class decorator: register a Scheduler subclass under ``name``."""

    def deco(cls: Type["Scheduler"]) -> Type["Scheduler"]:
        cls.name = name
        SCHEDULER_REGISTRY[name] = cls
        return cls

    return deco


def registered_schedulers() -> Tuple[str, ...]:
    """Every registered scheduler name (registration order)."""
    return tuple(SCHEDULER_REGISTRY)


def get_scheduler(cfg) -> Optional["Scheduler"]:
    """Resolve ``cfg.scheduler`` through the registry.

    ``"none"`` returns ``None`` — the static gate the engines test before
    compiling any scheduling op in.  A real scheduler validates that the
    subband budget is positive (``n_subbands`` is traced *data*, but a
    grid whose every point schedules zero devices is a config error).
    """
    if cfg.scheduler == "none":
        return None
    try:
        cls = SCHEDULER_REGISTRY[cfg.scheduler]
    except KeyError:
        raise KeyError(
            f"unknown scheduler {cfg.scheduler!r}; registered: "
            f"{', '.join(sorted(SCHEDULER_REGISTRY))}"
        ) from None
    if cfg.n_subbands < 1:
        raise ValueError(
            f"scheduler {cfg.scheduler!r} needs n_subbands >= 1; got "
            f"{cfg.n_subbands}"
        )
    return cls(cfg)


class Scheduler:
    """Base scheduler: a priority rule plus (optional) carried state.

    Subclasses override :meth:`priority` (higher = served first; the
    :func:`schedule` helper turns priorities into the transmit set with a
    traced ``rank < n_subbands`` cutoff) and — for stateful policies —
    set ``has_state`` and override :meth:`init_state` / :meth:`update`.
    State must be a single (m,) float32 vector: the engines carry it
    through the scan (dense) or bank it beside the error state keyed by
    device id (population), so one scalar per device is the contract.
    """

    name: str = "?"
    has_state: bool = False

    def __init__(self, cfg):
        self.cfg = cfg

    def init_state(self, m: int) -> jnp.ndarray:
        """(m,) carried scheduler state (only read when ``has_state``)."""
        return jnp.zeros((m,), jnp.float32)

    def priority(self, key, t, gains, state, n_subbands) -> jnp.ndarray:
        """(m,) per-device priority — pure in ``(key, t, gains, state)``.
        ``n_subbands`` is the traced subband budget (most policies ignore
        it; round_robin strides its cycle by it)."""
        raise NotImplementedError

    def update(self, state, gains, scheduled) -> jnp.ndarray:
        """Next round's carried state (only called when ``has_state``)."""
        return state


@register_scheduler("round_robin")
class RoundRobinScheduler(Scheduler):
    """Deterministic cycle: round t serves devices ``(t*S + j) mod M``.

    Realised as the priority ``-((idx - t*S) mod M)`` so the generic
    rank-cutoff in :func:`schedule` selects exactly the cycle window —
    ``S`` (``n_subbands``) stays traced data, rounded to the nearest
    device count for the cycle arithmetic.
    """

    def priority(self, key, t, gains, state, n_subbands):
        m = gains.shape[0]
        s = jnp.round(jnp.asarray(n_subbands, jnp.float32))
        offset = jnp.mod(jnp.asarray(t, jnp.float32) * s, m)
        idx = jnp.arange(m, dtype=jnp.float32)
        return -jnp.mod(idx - offset, m)


@register_scheduler("gain_ranked")
class GainRankedScheduler(Scheduler):
    """Max-SNR: serve the S devices with the largest received-power
    factors this round (post-geometry, post-fading)."""

    def priority(self, key, t, gains, state, n_subbands):
        return jnp.asarray(gains, jnp.float32)


@register_scheduler("prop_fair")
class PropFairScheduler(Scheduler):
    """Proportional fairness over a carried average-rate state.

    Priority is ``r_m / max(avg_m, eps)`` with the instantaneous rate
    proxy ``r_m = log1p(gain_m)``; after the round the served average
    updates as ``avg' = (1 - 1/tc) avg + (1/tc) r * scheduled`` with the
    static horizon ``tc = cfg.pf_horizon``.  A device that keeps getting
    served sees its average rise and its priority fall — the classic
    fairness/throughput interpolation (tc -> 1 approaches round-robin-
    like sharing, tc -> inf approaches max-SNR).
    """

    has_state = True
    _EPS = 1e-6

    def priority(self, key, t, gains, state, n_subbands):
        rate = jnp.log1p(jnp.asarray(gains, jnp.float32))
        return rate / jnp.maximum(state, self._EPS)

    def update(self, state, gains, scheduled):
        tc = jnp.float32(max(float(self.cfg.pf_horizon), 1.0))
        rate = jnp.log1p(jnp.asarray(gains, jnp.float32))
        served = rate * scheduled.astype(jnp.float32)
        return (1.0 - 1.0 / tc) * state + served / tc


def schedule(
    scheduler: Scheduler,
    key: jnp.ndarray,
    t,
    gains: jnp.ndarray,
    n_subbands,
    state: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """One round's transmit set: ``(scheduled (m,) bool, new_state)``.

    Ranks the scheduler's priorities (masked-out devices rank last: a
    phantom or churned-out device must never occupy a subband) and admits
    the top ``n_subbands`` — a traced compare, so the subband budget is a
    vmappable sweep axis.  ``jnp.argsort`` is stable, so priority ties
    break deterministically by device index and the result is bitwise
    reproducible.  ``new_state`` is ``None`` for stateless schedulers;
    callers own the masked-row keep-rule (a masked device's carried state
    must not evolve), matching the deltas contract in ``round_masked``.
    """
    prio = scheduler.priority(key, t, gains, state, n_subbands)
    if mask is not None:
        prio = jnp.where(mask, prio, -jnp.inf)
    order = jnp.argsort(-prio)
    rank = jnp.argsort(order).astype(jnp.float32)
    scheduled = rank < jnp.asarray(n_subbands, jnp.float32)
    if mask is not None:
        scheduled = scheduled & mask
    new_state = (
        scheduler.update(state, gains, scheduled) if scheduler.has_state else None
    )
    return scheduled, new_state
