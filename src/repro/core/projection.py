"""Compressive projection of sparsified gradients (paper §IV).

Two realisations:

* ``DenseProjector`` — the paper's A in R^{s_tilde x d}, entries
  N(0, 1/s_tilde), generated once from a shared seed (PS and devices agree).
  Used at paper scale (MNIST, d = 7850).
* ``BlockedProjector`` — TPU-native block-diagonal A: the flattened gradient
  is split into ``n_blocks`` chunks of ``block_size``; each chunk has an
  independent (s_block x block_size) matrix generated on-the-fly from a
  counter hash (kernels/).  Memory O(tile), shardable along d, AMP
  factorises per block.  See docs/DESIGN.md §4.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

# Host-side cache of generated dense measurement matrices, keyed by
# (seed, s_tilde, d).  Values are *numpy* arrays: an lru_cache of
# jnp.ndarray pins (s_tilde x d) device buffers across sweeps and
# backends (up to 8 full matrices of HBM leaked per multi-seed dense
# sweep).  Host bytes are cheap; ``jnp.asarray`` on use re-devices to
# whatever backend is current, and :func:`clear_dense_cache` frees
# everything explicitly.
_DENSE_CACHE: dict = {}
_DENSE_CACHE_MAX = 8


def clear_dense_cache() -> None:
    """Drop all cached dense measurement matrices (host copies)."""
    _DENSE_CACHE.clear()


def _dense_matrix(seed: int, s_tilde: int, d: int) -> jnp.ndarray:
    """Concrete (never traced) shared measurement matrix; cached per shape.

    Generation goes through jax.random so values are bitwise-identical to
    the historical device-cached version; only the *storage* is host-side.
    """
    key_t = (int(seed), int(s_tilde), int(d))
    host = _DENSE_CACHE.get(key_t)
    if host is None:
        with jax.ensure_compile_time_eval():
            key = jax.random.PRNGKey(seed)
            mat = jax.random.normal(key, (s_tilde, d), jnp.float32) / jnp.sqrt(
                jnp.float32(s_tilde))
        host = np.asarray(mat)
        if len(_DENSE_CACHE) >= _DENSE_CACHE_MAX:
            _DENSE_CACHE.pop(next(iter(_DENSE_CACHE)))
        _DENSE_CACHE[key_t] = host
    return jnp.asarray(host)


@dataclass(frozen=True)
class DenseProjector:
    d: int
    s_tilde: int
    seed: int = 0

    @property
    def out_dim(self) -> int:
        return self.s_tilde

    def matrix(self) -> jnp.ndarray:
        return _dense_matrix(self.seed, self.s_tilde, self.d)

    def project(self, v: jnp.ndarray) -> jnp.ndarray:
        return self.matrix() @ v

    def project_t(self, r: jnp.ndarray) -> jnp.ndarray:
        return self.matrix().T @ r

    def norm_bound(self) -> float:
        """sigma_max = sqrt(d/s_tilde) + 1 (paper App. A, Bai-Yin)."""
        return float(jnp.sqrt(self.d / self.s_tilde) + 1.0)


def _chunk_blocks_for(s_block: int, c: int, budget_bytes: int = 128 << 20) -> int:
    """How many blocks' A matrices fit the working-set budget at once."""
    return max(1, budget_bytes // max(s_block * c * 4, 1))


@dataclass(frozen=True)
class BlockedProjector:
    d: int
    block_size: int            # c
    s_block: int               # s_c  (per-block channel uses)
    seed: int = 0
    rademacher: bool = True
    use_kernel: bool = False

    @property
    def n_blocks(self) -> int:
        return -(-self.d // self.block_size)

    @property
    def chunk_blocks(self) -> int:
        return _chunk_blocks_for(self.s_block, self.block_size)

    @property
    def kernel_nb_tile(self) -> int:
        """Blocks batched per Pallas program (VMEM-budget analogue of the
        HBM-budget ``chunk_blocks``); the kernel wrappers clamp further."""
        from repro.kernels.ota_project import VMEM_TILE_BYTES
        return _chunk_blocks_for(self.s_block, self.block_size,
                                 budget_bytes=VMEM_TILE_BYTES)

    @property
    def d_pad(self) -> int:
        return self.n_blocks * self.block_size

    @property
    def out_dim(self) -> int:
        return self.n_blocks * self.s_block

    # -- layout ------------------------------------------------------------
    def to_blocks(self, v: jnp.ndarray) -> jnp.ndarray:
        v = jnp.pad(v, (0, self.d_pad - self.d))
        return v.reshape(self.n_blocks, self.block_size)

    def from_blocks(self, xb: jnp.ndarray) -> jnp.ndarray:
        return xb.reshape(self.d_pad)[: self.d]

    # -- ops ----------------------------------------------------------------
    def project(self, v: jnp.ndarray) -> jnp.ndarray:
        """(d,) -> (n_blocks * s_block,) flat projected signal."""
        return self.project_blocks(self.to_blocks(v)).reshape(-1)

    def project_blocks(self, xb: jnp.ndarray) -> jnp.ndarray:
        if not self.use_kernel and xb.shape[0] > self.chunk_blocks:
            return self._scan_op(xb, transpose=False)
        return ops.ota_project(xb, seed=self.seed, s_block=self.s_block,
                               rademacher=self.rademacher,
                               use_kernel=self.use_kernel)

    def project_t(self, y_flat: jnp.ndarray) -> jnp.ndarray:
        yb = y_flat.reshape(self.n_blocks, self.s_block)
        return self.from_blocks(self.project_t_blocks(yb))

    def project_t_blocks(self, yb: jnp.ndarray) -> jnp.ndarray:
        if not self.use_kernel and yb.shape[0] > self.chunk_blocks:
            return self._scan_op(yb, transpose=True)
        return ops.ota_project_t(yb, seed=self.seed, c=self.block_size,
                                 rademacher=self.rademacher,
                                 use_kernel=self.use_kernel)

    def _scan_op(self, xb: jnp.ndarray, transpose: bool) -> jnp.ndarray:
        """Chunked scan: generate each A chunk on the fly and consume it.

        The jnp analogue of the Pallas kernel's VMEM tiling — bounds the
        A working set to ``chunk_blocks`` blocks (docs/DESIGN.md §4.1).
        """
        n_blocks = xb.shape[0]
        ni = self.chunk_blocks
        pad = (-n_blocks) % ni
        xb_p = jnp.pad(xb, ((0, pad), (0, 0)))
        n_outer = (n_blocks + pad) // ni
        xs = xb_p.reshape(n_outer, ni, xb.shape[1])
        ids = jnp.arange(n_outer * ni, dtype=jnp.uint32).reshape(n_outer, ni)

        def gen(b):
            return ref.block_matrix_ref(self.seed, b, self.s_block,
                                        self.block_size, self.rademacher)

        def body(_, inp):
            ids_c, x_c = inp
            A = jax.vmap(gen)(ids_c)               # (ni, s_block, c)
            if transpose:
                y = jnp.einsum("isc,is->ic", A, x_c)
            else:
                y = jnp.einsum("isc,ic->is", A, x_c)
            return None, y

        _, ys = jax.lax.scan(body, None, (ids, xs))
        out_w = self.block_size if transpose else self.s_block
        return ys.reshape(-1, out_w)[:n_blocks]

    def block_matrix(self, b: int) -> jnp.ndarray:
        """Materialise one block (tests only)."""
        return ref.block_matrix_ref(self.seed, jnp.uint32(b), self.s_block,
                                    self.block_size, self.rademacher)

    def norm_bound(self) -> float:
        return float(jnp.sqrt(self.block_size / self.s_block) + 1.0)


def make_projector(cfg, d: int):
    """Build the projector described by an OTAConfig for a d-dim gradient."""
    if cfg.projection == "dense":
        s = cfg.s_for(d)
        # analog frame reserves 2 channel uses (mean slot + scale slot)
        proj = DenseProjector(d=d, s_tilde=max(s - 2, 1), seed=cfg.seed)
        proj.matrix()   # materialise eagerly (outside any trace)
        return proj
    if cfg.projection == "blocked":
        c = cfg.block_size
        s_block = max(2, int(round(cfg.s_frac * c)))
        return BlockedProjector(d=d, block_size=c, s_block=s_block,
                                seed=cfg.seed, rademacher=cfg.rademacher,
                                use_kernel=cfg.use_kernel)
    raise ValueError(f"unknown projection {cfg.projection!r}")
