"""Per-iteration transmit-power schedules P_t (paper §III Remark 1, eq. 45).

All schedules satisfy the average-power constraint (1/T) sum_t P_t <= P_bar.
Schedules are pure functions of (t, T, p_avg) so they can be evaluated inside
jit (t traced) or on the host (numpy) when precomputing bit budgets.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

SCHEDULES = ("constant", "lh_stair", "lh_steps", "hl_steps")


def power_at(t, total_steps: int, p_avg: float, schedule: str = "constant"):
    """P_t for iteration t (0-based). Works on traced or numpy scalars."""
    T = total_steps
    xp = jnp if not isinstance(t, (int, np.integer, np.ndarray)) else np
    if schedule == "constant":
        return xp.full_like(xp.asarray(t, xp.float32), p_avg) * 1.0
    if schedule == "lh_stair":
        # linear 0.5*P .. 1.5*P  (paper eq. 45a with P=200: 100 -> 300)
        frac = xp.asarray(t, xp.float32) / max(T - 1, 1)
        return p_avg * (0.5 + frac)
    third = max(T // 3, 1)
    idx = xp.minimum(xp.asarray(t) // third, 2)
    if schedule == "lh_steps":
        levels = xp.asarray([0.5, 1.0, 1.5], xp.float32) * p_avg
    elif schedule == "hl_steps":
        levels = xp.asarray([1.5, 1.0, 0.5], xp.float32) * p_avg
    else:
        raise ValueError(f"unknown power schedule {schedule!r}")
    return levels[idx]


def schedule_array(total_steps: int, p_avg: float, schedule: str) -> np.ndarray:
    """Host-side P_t for t = 0..T-1 (used to precompute digital bit budgets)."""
    ts = range(total_steps)
    ps = [float(power_at(np.int64(t), total_steps, p_avg, schedule)) for t in ts]
    return np.asarray(ps, np.float64)


def verify_average_power(ps: np.ndarray, p_avg: float, tol: float = 1e-6) -> bool:
    return float(ps.mean()) <= p_avg * (1 + tol)
