"""Fully-sharded aggregation driver (shard_map manual over data x model).

Phase 2 of the distributed train step (see train/trainer.py): every device
owns a (d_pad / n_shards) slice of its data-replica's gradient.  This module
provides the *generic* slice driver :func:`sharded_round` — it pre-averages
edge-site groups, runs the scheme's ``encode_slice``, superposes the frame
over the device axes (the MAC psum), injects AWGN for analog schemes, and
hands the observation to ``decode_slice``.  All scheme-specific pipeline
logic (EF -> threshold sparsify -> blocked projection -> power scaling ->
per-block AMP for A-DSGD) lives on the scheme classes in
:mod:`repro.core.schemes`; this driver never branches on a scheme name.

Cross-shard coordination inside the A-DSGD hooks stays tiny and explicit:
the top-k threshold gathers 65k |g| samples, the frame energy / mean / scale
slots are scalar psums.  Per-shard measurement matrices derive from a
shard-folded seed (the PS uses the same fold — consistency by construction).
No d-sized tensor is ever replicated, gathered, or scanned across shards.

The helpers :func:`proj_forward` / :func:`amp_blocked` are the traced-seed
blocked projection + AMP realisation: a chunked jnp scan by default, or the
chunk-batched projection kernels (kernels/ota_project.py) and the fused
single-launch AMP kernel (kernels/amp_fused.py) when the scheme passes
``use_kernel=True`` — both kernels take the traced shard-folded seed
through an SMEM operand, so PS and devices stay consistent by construction.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel
from repro.kernels import ref


# ---------------------------------------------------------------------------
# traced-seed blocked projection + AMP (the jnp/XLA realisation)
# ---------------------------------------------------------------------------


def proj_forward(xb: jnp.ndarray, seed_u32, s_block: int,
                 chunk_blocks: int, use_kernel: bool = False) -> jnp.ndarray:
    """xb (n_blocks, c) -> (n_blocks, s_block); A generated per chunk.

    ``use_kernel=True`` lowers through the chunk-batched Pallas projection
    kernel (kernels/ota_project.py) — the traced shard-folded seed passes
    straight through its SMEM operand.
    """
    n_blocks, c = xb.shape
    if use_kernel:
        from repro.kernels import ops
        return ops.ota_project(xb, seed=seed_u32, s_block=s_block,
                               rademacher=True, use_kernel=True)
    ni = min(chunk_blocks, n_blocks)
    pad = (-n_blocks) % ni
    xb_p = jnp.pad(xb, ((0, pad), (0, 0)))
    n_outer = (n_blocks + pad) // ni
    xs = xb_p.reshape(n_outer, ni, c)
    ids = jnp.arange(n_outer * ni, dtype=jnp.uint32).reshape(n_outer, ni)

    def body(_, inp):
        ids_c, x_c = inp
        A = jax.vmap(lambda b: ref.block_matrix_ref(seed_u32, b, s_block,
                                                    c, True))(ids_c)
        return None, jnp.einsum("isc,ic->is", A, x_c)

    _, ys = jax.lax.scan(body, None, (ids, xs))
    return ys.reshape(-1, s_block)[:n_blocks]


def amp_blocked(yb: jnp.ndarray, seed_u32, c: int, iters: int,
                chunk_blocks: int, threshold_mult: float = 1.3,
                debias: bool = True, id_offset=0,
                use_kernel: bool = False) -> jnp.ndarray:
    """Per-block AMP with traced seed; A generated ONCE per block per decode.

    Thin re-export of :func:`repro.core.amp.amp_blocked_core` (the single
    chunked implementation: jnp scan, or the fused single-launch Pallas
    kernel when ``use_kernel=True``).

    id_offset (traced ok): global index of this slice's first block — lets a
    device decode a sub-range of blocks with the encoder's global block ids.
    """
    from repro.core.amp import amp_blocked_core
    return amp_blocked_core(yb, seed_u32, c, iters, chunk_blocks,
                            threshold_mult, debias, rademacher=True,
                            id_offset=id_offset, use_kernel=use_kernel)


def psum_all(x, axes: Sequence[str]):
    for ax in axes:
        x = jax.lax.psum(x, ax)
    return x


# ---------------------------------------------------------------------------
# the generic sharded-slice driver
# ---------------------------------------------------------------------------


def sharded_round(scheme, g_slice: jnp.ndarray, delta_slice: jnp.ndarray,
                  step, key, ctx) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """One aggregation round on gradient slices for any scheme with slice
    hooks (manual over ``ctx.device_axes`` + ``ctx.shard_axes``).

    g_slice, delta_slice: (d_local,) — this device-replica's shard of the
    ctx.d_pad-dim vector; d_local = d_pad / n_shards.

    The scheme's ``encode_slice`` returns a frame dict with a ``"body"``
    array and optional ``"slots"`` scalars; this driver psums both over the
    device axes (the MAC superposition — the body optionally in
    ``ctx.frame_dtype``, e.g. bf16: its quantisation noise is far below the
    channel AWGN sigma^2), adds AWGN once per channel slice when the scheme
    is analog, and calls ``decode_slice`` on the observation.
    """
    from repro.core.schemes import (
        channel_amp, round_sigma2, sharded_channel_draw, shard_info,
    )
    if ctx.key_salt:
        key = jax.random.fold_in(key, ctx.key_salt)
    g_slice = g_slice.astype(jnp.float32)
    group_size = ctx.group_size
    if ctx.groups is not None:
        g_slice = jax.lax.psum(
            g_slice, ctx.device_axes[-1],
            axis_index_groups=[list(g) for g in ctx.groups]) / group_size

    if scheme.analog:
        # per-device channel draw (same h on every shard of a device-replica:
        # the full-M realisation is evaluated from the shared round key and
        # indexed by the device row, never by the shard index)
        draw = sharded_channel_draw(scheme, key, step, ctx)
        ctx = ctx.with_p_factor(draw.p_factor)
    frame, new_delta, metrics = scheme.encode_slice(
        g_slice, delta_slice, step, key, ctx)
    if scheme.analog:
        amp = channel_amp(draw)
        frame = {k: (v * amp.astype(v.dtype) if v is not None else None)
                 for k, v in frame.items()}
        new_delta = jnp.where(draw.active, new_delta,
                              scheme.silent_state(g_slice, delta_slice,
                                                  new_delta))

    # --- the MAC: superposition over device axes + AWGN ---------------------
    body = frame["body"]
    if ctx.frame_dtype is not None and scheme.analog:
        # the narrow-psum optimisation only applies to analog frames, whose
        # quantisation noise hides under the channel AWGN; non-analog
        # aggregation (ideal benchmark, digital) stays exact in f32
        body = body.astype(ctx.frame_dtype)
    y_body = psum_all(body, ctx.device_axes).astype(jnp.float32)
    slots = frame.get("slots")
    y_slots = (psum_all(slots, ctx.device_axes)
               if slots is not None else None)
    if group_size > 1:
        y_body = y_body / group_size
        if y_slots is not None:
            y_slots = y_slots / group_size
    if scheme.analog:
        sigma2 = round_sigma2(scheme, draw)
        shard_idx, n_shards = shard_info(ctx.shard_axes)
        body_key = jax.random.fold_in(key, shard_idx.astype(jnp.int32))
        n_sites = (len(ctx.groups)
                   if ctx.site_mac and ctx.groups is not None else 1)
        if n_sites > 1:
            # hierarchical MAC: each edge-site group's partial sum carries
            # its own receiver AWGN per channel slice (summed by the PS
            # combine), mirroring round_sharded's site path
            y_body = y_body + channel.site_awgn(
                body_key, y_body.shape, sigma2, n_sites,
                site_noise_scale=ctx.site_noise_scale)
            if y_slots is not None:
                slot_key = jax.random.fold_in(key, n_shards + 7)
                y_slots = y_slots + channel.site_awgn(
                    slot_key, y_slots.shape, sigma2, n_sites,
                    site_noise_scale=ctx.site_noise_scale)
        else:
            y_body = y_body + channel.awgn(body_key, y_body.shape, sigma2)
            if y_slots is not None:
                slot_key = jax.random.fold_in(key, n_shards + 7)
                y_slots = y_slots + channel.awgn(slot_key, y_slots.shape,
                                                 sigma2)

    ghat_slice = scheme.decode_slice({"body": y_body, "slots": y_slots},
                                     step, ctx)
    return ghat_slice, new_delta, metrics
