"""Fully-sharded OTA aggregation phase (shard_map manual over data x model).

Phase 2 of the distributed train step (see train/trainer.py): every device
owns a (d_pad / n_model) slice of its data-replica's gradient.  All of the
paper's per-device pipeline is slice-local:

  EF add -> threshold sparsify -> blocked projection -> power scaling
  -> MAC psum over the device axes -> AWGN -> per-block AMP -> ghat slice

Cross-shard coordination is tiny and explicit: the top-k threshold gathers
65k |g| samples, the frame energy / mean / scale slots are scalar psums.
Per-shard measurement matrices derive from a shard-folded seed (the PS uses
the same fold — consistency by construction).  No d-sized tensor is ever
replicated, gathered, or scanned across shards.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OTAConfig
from repro.core import channel
from repro.core.amp import soft_threshold
from repro.kernels import ref


# ---------------------------------------------------------------------------
# traced-seed blocked projection + AMP (the jnp/XLA realisation; on TPU the
# Pallas kernels in kernels/ota_project.py implement the same tiling in VMEM)
# ---------------------------------------------------------------------------


def proj_forward(xb: jnp.ndarray, seed_u32, s_block: int,
                 chunk_blocks: int) -> jnp.ndarray:
    """xb (n_blocks, c) -> (n_blocks, s_block); A generated per chunk."""
    n_blocks, c = xb.shape
    ni = min(chunk_blocks, n_blocks)
    pad = (-n_blocks) % ni
    xb_p = jnp.pad(xb, ((0, pad), (0, 0)))
    n_outer = (n_blocks + pad) // ni
    xs = xb_p.reshape(n_outer, ni, c)
    ids = jnp.arange(n_outer * ni, dtype=jnp.uint32).reshape(n_outer, ni)

    def body(_, inp):
        ids_c, x_c = inp
        A = jax.vmap(lambda b: ref.block_matrix_ref(seed_u32, b, s_block,
                                                    c, True))(ids_c)
        return None, jnp.einsum("isc,ic->is", A, x_c)

    _, ys = jax.lax.scan(body, None, (ids, xs))
    return ys.reshape(-1, s_block)[:n_blocks]


def amp_blocked(yb: jnp.ndarray, seed_u32, c: int, iters: int,
                chunk_blocks: int, threshold_mult: float = 1.3,
                debias: bool = True, id_offset=0) -> jnp.ndarray:
    """Per-block AMP with traced seed; A generated once per chunk.

    id_offset (traced ok): global index of this slice's first block — lets a
    device decode a sub-range of blocks with the encoder's global block ids.
    """
    n_blocks, s_block = yb.shape
    ni = min(chunk_blocks, n_blocks)
    pad = (-n_blocks) % ni
    yb_p = jnp.pad(yb, ((0, pad), (0, 0)))
    n_outer = (n_blocks + pad) // ni
    ys = yb_p.reshape(n_outer, ni, s_block)
    ids = (jnp.arange(n_outer * ni, dtype=jnp.uint32)
           + jnp.asarray(id_offset, jnp.uint32)).reshape(n_outer, ni)

    def chunk_amp(_, inp):
        ids_c, y_c = inp
        A = jax.vmap(lambda b: ref.block_matrix_ref(seed_u32, b, s_block,
                                                    c, True))(ids_c)

        def body(_, carry):
            x, z = carry
            sigma_hat = jnp.linalg.norm(z, axis=1, keepdims=True) / jnp.sqrt(
                jnp.float32(s_block))
            r = x + jnp.einsum("isc,is->ic", A, z)
            x_new = soft_threshold(r, threshold_mult * sigma_hat)
            onsager = z * (jnp.sum(x_new != 0.0, axis=1, keepdims=True)
                           / s_block)
            z_new = y_c - jnp.einsum("isc,ic->is", A, x_new) + onsager
            return x_new, z_new

        x0 = jnp.zeros((ni, c), y_c.dtype)
        x, _ = jax.lax.fori_loop(0, iters, body, (x0, y_c))
        if debias:
            ax = jnp.einsum("isc,ic->is", A, x)
            num = jnp.sum(ax * y_c, axis=1, keepdims=True)
            den = jnp.maximum(jnp.sum(ax * ax, axis=1, keepdims=True), 1e-12)
            x = x * (num / den)
        return None, x

    _, xs = jax.lax.scan(chunk_amp, None, (ids, ys))
    return xs.reshape(-1, c)[:n_blocks]


# ---------------------------------------------------------------------------
# the sharded aggregation round
# ---------------------------------------------------------------------------


def _psum_all(x, axes: Sequence[str]):
    for ax in axes:
        x = jax.lax.psum(x, ax)
    return x


def sharded_ota_round(g_slice: jnp.ndarray, delta_slice: jnp.ndarray,
                      step, key, cfg: OTAConfig, *,
                      device_axes: Sequence[str], shard_axes: Sequence[str],
                      m_devices: int, d_pad: int, p_sched: jnp.ndarray,
                      pre_average_groups=None,
                      sample_per_shard: int = 4096,
                      chunk_blocks: int = 8,
                      p_scale: float = 1.0,
                      key_salt: int = 0,
                      frame_dtype=None,
                      shard_decode: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """One A-DSGD round on gradient slices (manual over device+shard axes).

    g_slice, delta_slice: (d_local,) — this device-replica's shard of the
    d_pad-dim vector; d_local = d_pad / n_shards.

    Optimisation knobs (§Perf, all default off = paper-faithful baseline):
      p_scale      — fraction of P_t granted to this sub-frame (sliced layout
                     splits power between sharded/replicated sub-vectors)
      frame_dtype  — psum the MAC body in bf16 (quantisation noise is far
                     below the channel AWGN sigma^2)
      shard_decode — split the redundant PS AMP across the device axes and
                     all-gather the decoded slices (compute / M for +slice
                     bytes of collective)
    """
    shard_axes = tuple(shard_axes)
    n_shards = 1
    shard_idx = jnp.zeros((), jnp.uint32)
    for ax in shard_axes:
        sz = jax.lax.axis_size(ax)
        shard_idx = shard_idx * sz + jax.lax.axis_index(ax).astype(jnp.uint32)
        n_shards *= sz
    key = jax.random.fold_in(key, key_salt) if key_salt else key
    d_local = g_slice.shape[0]
    g_slice = g_slice.astype(jnp.float32)
    group_size = 1
    if pre_average_groups is not None:
        group_size = len(pre_average_groups[0])
        g_slice = jax.lax.psum(g_slice, device_axes[-1],
                               axis_index_groups=pre_average_groups) / group_size

    # --- error feedback + sampled global threshold -------------------------
    g_ec = g_slice + delta_slice.astype(jnp.float32)
    k = max(1, int(cfg.k_frac * cfg.s_frac * d_pad))
    stride = max(1, d_local // sample_per_shard)
    n_s = d_local // stride
    local_sample = jnp.abs(jax.lax.slice_in_dim(g_ec, 0, n_s * stride,
                                                stride, axis=0))
    all_samples = (jax.lax.all_gather(local_sample, shard_axes).reshape(-1)
                   if shard_axes else local_sample)
    q = 1.0 - k / d_pad
    tau = jnp.quantile(all_samples, q)
    keep = jnp.abs(g_ec) >= tau
    g_sp = jnp.where(keep, g_ec, 0.0)
    new_delta = (g_ec - g_sp).astype(delta_slice.dtype)

    # --- blocked projection (per-shard folded seed) -------------------------
    c = cfg.block_size
    s_block = max(2, int(round(cfg.s_frac * c)))
    n_blocks_local = d_local // c
    seed_u32 = ref.splitmix32(jnp.uint32(cfg.seed)
                              ^ shard_idx.astype(jnp.uint32))
    yb = proj_forward(g_sp.reshape(n_blocks_local, c), seed_u32, s_block,
                      chunk_blocks)                      # (nb_local, s_block)

    # --- power scaling (paper eq. 13/22; scalars psum'd over shards) -------
    p_t = p_sched[jnp.minimum(step, p_sched.shape[0] - 1)] * p_scale
    use_mr = (jnp.asarray(step) < cfg.mean_removal_steps).astype(jnp.float32)
    s_tilde = float((d_pad // c) * s_block)              # global channel dim
    local_sum = jnp.sum(yb)
    mu = use_mr * _psum_all(local_sum, shard_axes) / s_tilde
    local_energy = jnp.sum(yb * yb)
    energy = _psum_all(local_energy, shard_axes)
    energy_az = energy - (s_tilde - 1.0) * mu * mu + 1.0
    alpha = p_t / jnp.maximum(energy_az, 1e-12)
    ra = jnp.sqrt(alpha)
    body_local = ra * (yb - mu)
    mu_slot = ra * mu
    scale_slot = ra

    # --- the MAC: superposition over device axes + AWGN ---------------------
    if frame_dtype is not None:
        body_local = body_local.astype(frame_dtype)
    y_mac = _psum_all(body_local, device_axes).astype(jnp.float32)
    mu_mac = _psum_all(mu_slot, device_axes)
    scale_mac = _psum_all(scale_slot, device_axes)
    if group_size > 1:
        y_mac, mu_mac, scale_mac = (t / group_size
                                    for t in (y_mac, mu_mac, scale_mac))
    body_key = jax.random.fold_in(key, shard_idx.astype(jnp.int32))
    y_mac = y_mac + channel.awgn(body_key, y_mac.shape, cfg.sigma2)
    slot_key = jax.random.fold_in(key, n_shards + 7)
    zslots = channel.awgn(slot_key, (2,), cfg.sigma2)
    mu_mac = mu_mac + zslots[0]
    scale_mac = scale_mac + zslots[1]

    # --- PS: normalise + AMP -------------------------------------------------
    scale = jnp.where(jnp.abs(scale_mac) > 1e-12, scale_mac, 1.0)
    y_norm = (y_mac + use_mr * mu_mac) / scale
    if shard_decode and device_axes:
        # the y slice is identical on every device row after the psum —
        # decode 1/M of its blocks per row and all-gather the results
        n_rows = 1
        row_idx = jnp.zeros((), jnp.int32)
        for ax in device_axes:
            sz = jax.lax.axis_size(ax)
            row_idx = row_idx * sz + jax.lax.axis_index(ax)
            n_rows *= sz
        nb = y_norm.shape[0]
        nb_pad = -(-nb // n_rows) * n_rows
        y_p = jnp.pad(y_norm, ((0, nb_pad - nb), (0, 0)))
        per = nb_pad // n_rows
        y_mine = jax.lax.dynamic_slice_in_dim(y_p, row_idx * per, per, 0)
        # block ids must stay global: offset the hash ids via a row-salted
        # projector is WRONG (encode used global ids) -> decode with global
        # ids by passing an id offset through amp_blocked_offset
        x_mine = amp_blocked(y_mine, seed_u32, c, cfg.amp_iters,
                             chunk_blocks,
                             id_offset=(row_idx * per).astype(jnp.uint32))
        xg = jax.lax.all_gather(x_mine, device_axes, tiled=True)
        ghat_slice = xg[:nb].reshape(-1)
    else:
        ghat_slice = amp_blocked(y_norm, seed_u32, c, cfg.amp_iters,
                                 chunk_blocks).reshape(-1)
    metrics = {"alpha": alpha, "p_t": p_t, "tau": tau,
               "frame_power": alpha * (energy - (s_tilde - 1.0) * mu * mu
                                       + 1.0)}
    return ghat_slice, new_delta, metrics
