from repro.core.schemes import (  # noqa: F401
    MACContext, PAPER_SCHEMES, Scheme, get_scheme, register_scheme,
    registered_schemes, round_sharded, round_simulated,
)
from repro.core.projection import (  # noqa: F401
    BlockedProjector, DenseProjector, make_projector,
)


def __getattr__(name: str):
    if name == "SCHEMES":          # live view of the scheme registry
        return registered_schemes()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
