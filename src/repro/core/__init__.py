from repro.core.aggregators import Aggregator, SCHEMES, make_aggregator  # noqa: F401
from repro.core.projection import (  # noqa: F401
    BlockedProjector, DenseProjector, make_projector,
)
