"""Channel-model subsystem: fading processes + CSI models as a pluggable axis.

The paper's follow-ups extend over-the-air DSGD from the AWGN MAC to fading
MACs: *Federated Learning over Wireless Fading Channels* (Amiri & Gunduz,
arXiv:1907.09769) keeps CSI at the transmitters and truncation-inverts the
fade, and *Collaborative Machine Learning at the Wireless Edge with Blind
Transmitters* (Amiri, Duman & Gunduz, arXiv:1907.03909) drops transmitter
CSI entirely and recovers alignment at a multi-antenna PS.  This module
factors the *channel* out of the scheme classes so the two axes compose:

* **fading process** — how the complex gains ``h_m(t)`` evolve over rounds:

  - ``static``       block-flat: one CN(0,1) draw per run, constant in t
  - ``iid``          a fresh CN(0,1) draw every round (the default — the
                     behaviour of the pre-existing ``a_dsgd_fading`` scheme)
  - ``gauss_markov`` time-correlated: the stationary AR(1) process
                     ``h_t = rho h_{t-1} + sqrt(1-rho^2) w_t`` realised as a
                     windowed moving average (see :func:`process_gains`), so
                     ``h_t`` is a pure function of ``(seed, t)`` — no carried
                     state, which is what lets compiled sweep runs stay one
                     ``jit(lax.scan)`` and lets grids vmap over ``rho``.

* **CSI model** — what the transmitter knows about its gain:

  - ``perfect``  the device sees ``h_m`` exactly (1907.09769 §III)
  - ``noisy``    the device sees an MMSE-style estimate
                 ``h_hat = h + e``, ``e ~ CN(0, csi_err_var)``
  - ``none``     no CSI at the device (1907.03909): plain power-scaled
                 superposition; the PS recovers coherence by combining over
                 K antennas (channel hardening — see
                 :func:`blind_combiner_stats`)

Everything here is a pure function of ``(keys, step)``: every draw is
reproducible from the round key and/or the run-level ``fading_key``, nothing
carries state across rounds, and all the "data-like" parameters
(``csi_err_var``, ``fading_threshold``, ``fading_rho``) enter as traced
multiplies/compares, so they ride the compiled sweep engine's vmapped axes
(docs/DESIGN.md §8).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

#: recognised fading processes / CSI models (validated by spec_from_cfg)
PROCESSES = ("static", "iid", "gauss_markov")
CSI_MODELS = ("perfect", "noisy", "none")

#: salt decorrelating the run-level fading stream from every other consumer
#: of OTAConfig.seed (projector seeds, data splits)
FADING_SEED_SALT = 0x0FAD

#: offset keeping ``step - i`` folds positive for any practical horizon
_STEP_OFFSET = 1 << 20


@dataclass(frozen=True)
class FadingSpec:
    """Static description of the channel model (shape-/trace-defining bits).

    The *values* of ``rho`` / ``csi_err_var`` / ``threshold`` live on the
    scheme object as traced-friendly scalars (swappable per grid point via
    ``Scheme.with_overrides``); this spec only pins what changes the traced
    program structure: which process/CSI branch is generated, the MA window,
    and the PS antenna count.
    """

    process: str = "iid"  # static | iid | gauss_markov
    csi: str = "perfect"  # perfect | noisy | none
    window: int = 64  # gauss_markov MA window W
    ps_antennas: int = 32  # K receive antennas (blind PS combining)


def spec_from_cfg(cfg) -> FadingSpec:
    """Build the spec from an OTAConfig, validating the names."""
    if cfg.fading_process not in PROCESSES:
        raise ValueError(
            f"unknown fading_process {cfg.fading_process!r}; known: {PROCESSES}"
        )
    return FadingSpec(
        process=cfg.fading_process,
        window=cfg.fading_window,
        ps_antennas=cfg.ps_antennas,
    )


def fading_base_key(seed: int) -> jnp.ndarray:
    """Run-level key anchoring the static / gauss_markov gain streams.

    Derived from ``OTAConfig.seed`` — the correlated-fading *realisation* is
    a property of the run configuration, not of the per-round key stream, so
    a ``seed`` sweep axis (which shifts the round keys) holds the fading
    sample path fixed across replicas: common random numbers for paired
    comparisons.
    """
    return jax.random.PRNGKey(seed ^ FADING_SEED_SALT)


def complex_normals(key: jnp.ndarray, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(re, im) of m i.i.d. CN(0,1) draws — the exact draw layout of the
    legacy ``channel.rayleigh_gains`` (bitwise-pinned by the goldens)."""
    re, im = jax.random.normal(key, (2, m)) / jnp.sqrt(2.0)
    return re, im


def magnitude(re: jnp.ndarray, im: jnp.ndarray) -> jnp.ndarray:
    """|h| computed exactly as ``channel.rayleigh_gains`` does."""
    return jnp.sqrt(re * re + im * im)


def process_gains(
    spec: FadingSpec,
    fkey: jnp.ndarray,
    round_key: jnp.ndarray,
    step,
    m: int,
    rho=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Complex gains (re, im), each (m,), for one round — pure in (keys, t).

    ``iid`` draws from the (already salted) per-round ``round_key`` — for
    the default process this is bitwise the pre-existing ``a_dsgd_fading``
    draw.  ``static`` draws from the run-level ``fkey`` only, so every round
    sees the same block-flat realisation.  ``gauss_markov`` realises the
    stationary AR(1) Gaussian process through its moving-average expansion

        h_t = sum_{i>=0} c_i w_{t-i},   c_i ∝ rho^i,

    truncated at ``spec.window`` terms and renormalised to unit variance:
    the innovations ``w_j`` come from ``fold_in(fkey, j)``, so ``h_t`` is a
    pure function of ``(fkey, t)`` with autocorrelation ``rho^|dt|`` (up to
    the truncation factor ``(1-rho^{2(W-dt)})/(1-rho^{2W})``).  Statelessness
    is the point: the same expression evaluates inside a compiled scan, in
    the looped reference, and under vmap — and ``rho`` enters only as a
    traced weight vector, so it can ride a vmapped sweep axis.
    """
    if spec.process == "iid":
        return complex_normals(round_key, m)
    if spec.process == "static":
        return complex_normals(fkey, m)
    # gauss_markov
    w = spec.window
    rho = jnp.asarray(0.9 if rho is None else rho, jnp.float32)
    idx = jnp.arange(w, dtype=jnp.float32)
    c = rho**idx
    c = c / jnp.sqrt(jnp.sum(c * c))

    def draw(i):
        k = jax.random.fold_in(fkey, jnp.asarray(step, jnp.int32) - i + _STEP_OFFSET)
        return jnp.stack(complex_normals(k, m))  # (2, m)

    draws = jax.vmap(draw)(jnp.arange(w, dtype=jnp.int32))  # (W, 2, m)
    h = jnp.tensordot(c, draws, axes=1)  # (2, m)
    return h[0], h[1]


def csi_estimate(
    re: jnp.ndarray,
    im: jnp.ndarray,
    key: jnp.ndarray,
    err_var,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Noisy CSI: ``h_hat = h + e``, ``e ~ CN(0, err_var)``.

    ``err_var`` is a traced scalar (a vmappable sweep axis); at exactly 0 the
    additive error is ``0.0 * e`` — IEEE-exact, so ``h_hat`` is bitwise ``h``
    and the csi-err scheme degrades to perfect-CSI truncated inversion with
    no special-casing (pinned by the goldens).
    """
    m = re.shape[0]
    e_re, e_im = complex_normals(key, m)
    s = jnp.sqrt(jnp.asarray(err_var, jnp.float32))
    return re + s * e_re, im + s * e_im


def misalignment_gain(re, im, est_re, est_im, err_var) -> jnp.ndarray:
    """Effective real gain of estimate-driven channel inversion.

    A device that pre-inverts with its *estimate* transmits ``x / h_hat``;
    the channel applies the *true* ``h``, so the coherent (in-phase)
    component arrives scaled by ``Re(h / h_hat) = Re(h conj(h_hat)) /
    |h_hat|^2`` — under-unity on average, and noisier as the estimation
    error grows (the quadrature leakage ``Im(h/h_hat)`` is orthogonal to the
    real frame and drops out of coherent detection).  At ``err_var == 0``
    numerator and denominator are the *same expression*, so the ratio is
    exactly 1.0 and the fading-scheme fast path is preserved bitwise (the
    explicit ``where`` keeps that exactness even when ``err_var`` is a
    traced zero inside a sweep grid).
    """
    num = re * est_re + im * est_im
    den = est_re * est_re + est_im * est_im
    g = num / jnp.maximum(den, 1e-12)
    return jnp.where(jnp.asarray(err_var, jnp.float32) > 0.0, g, jnp.ones_like(g))


def blind_combiner_stats(
    re: jnp.ndarray,
    im: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PS-side combining statistics for blind transmitters (1907.03909).

    ``re, im``: (m, K) per-device/per-antenna true gains.  The K-antenna PS
    knows its receive CSI and combines the antenna observations with the
    conjugate of the *superposed* channel ``f_k = sum_m h_{m,k}`` — the only
    combiner available post-superposition — normalised by ``K E|h|^2 = K``:

        y_comb = (1/K) sum_k conj(f_k) y_k
               = sum_m g_m x_m + (1/K) sum_k conj(f_k) z_k

    Returns ``(gain, noise_scale)``: ``gain[m] = Re(g_m)`` — the per-device
    effective real gain, ``1 + O(sqrt(M/K))`` by channel hardening — and the
    scalar ``noise_scale = sum_k |f_k|^2 / K^2`` multiplying the AWGN
    variance (``~ M/K`` in expectation).  As K grows both converge (gains
    to 1, noise to 0): the blind MAC hardens into a noiseless ideal link,
    which is the paper's asymptotic result.
    """
    k = re.shape[1]
    f_re = jnp.sum(re, axis=0)  # (K,)
    f_im = jnp.sum(im, axis=0)
    gain = (re @ f_re + im @ f_im) / k  # Re(conj(f) h), summed over antennas
    noise_scale = jnp.sum(f_re * f_re + f_im * f_im) / (k * k)
    return gain, noise_scale
