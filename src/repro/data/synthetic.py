"""Deterministic synthetic data pipelines (offline container — no downloads).

Two pipelines:

* :class:`TokenStream` — language-model token batches for the framework
  archs: a fixed-seed Markov-ish stream (n-gram mixing) so the loss has
  learnable structure; sharded per data-parallel replica.
* :func:`make_classification` — the paper-repro surrogate for MNIST: 10-class
  28x28 "images" drawn from class-conditioned low-rank Gaussian templates
  (same dims: 60k train / 10k test, d = 7850 for the single-layer model).
  All §VI claims are validated in *relative* terms on this surrogate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclass
class TokenStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, shard): tokens (B/n_shards, L+?)."""
        assert self.batch % n_shards == 0
        b = self.batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        # structured stream: x_{t} depends on x_{t-1} via a fixed permutation
        # mixed with noise -> learnable bigram structure
        perm_rng = np.random.default_rng(self.seed)
        perm = perm_rng.permutation(self.vocab)
        toks = np.empty((b, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        noise = rng.integers(0, self.vocab, (b, self.seq_len))
        follow = rng.random((b, self.seq_len)) < 0.8
        for t in range(1, self.seq_len):
            toks[:, t] = np.where(follow[:, t], perm[toks[:, t - 1]],
                                  noise[:, t])
        return {"tokens": toks}


# ---------------------------------------------------------------------------
# paper-repro classification surrogate
# ---------------------------------------------------------------------------


def make_classification(n_train: int = 60000, n_test: int = 10000,
                        n_classes: int = 10, dim: int = 784, seed: int = 0,
                        rank: int = 16, noise: float = 0.9):
    """Class-conditioned low-rank Gaussian images, normalised like MNIST."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(n_classes, dim)).astype(np.float32)
    factors = rng.normal(size=(n_classes, rank, dim)).astype(np.float32) / np.sqrt(rank)

    def sample(n, seed2):
        r = np.random.default_rng(seed2)
        y = r.integers(0, n_classes, n)
        z = r.normal(size=(n, rank)).astype(np.float32)
        x = templates[y] + np.einsum("nr,nrd->nd", z, factors[y]) * 0.5
        x = x + noise * r.normal(size=(n, dim)).astype(np.float32)
        x = (x - x.mean(1, keepdims=True)) / (x.std(1, keepdims=True) + 1e-6)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train, seed + 1)
    x_te, y_te = sample(n_test, seed + 2)
    return (x_tr, y_tr), (x_te, y_te)


def federated_split(x: np.ndarray, y: np.ndarray, m: int, b: int,
                    iid: bool = True, n_classes: int = 10, seed: int = 0,
                    kind: str = "", beta: float = 1.0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Assign B samples to each of M devices (paper §VI).

    Thin front-end over :mod:`repro.data.partition`.  ``iid`` keeps the
    paper's two protocols (uniform / two classes per device); ``kind``
    overrides it with any registered partitioner (``iid`` |
    ``label_shards`` | ``dirichlet`` with bias knob ``beta`` — see
    ``docs/EXPERIMENTS.md``).  Returns (x_dev (M, B, d), y_dev (M, B)).
    """
    from repro.data.partition import make_partition
    if not kind:
        kind = "iid" if iid else "label_shards"
    return make_partition(x, y, m, b, kind=kind, beta=beta,
                          n_classes=n_classes, seed=seed)
