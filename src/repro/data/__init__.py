from repro.data.partition import (  # noqa: F401
    PARTITION_KINDS, label_bias, label_shard_assignment, make_partition,
    partition_dirichlet, partition_iid, partition_label_shards,
)
from repro.data.synthetic import (  # noqa: F401
    TokenStream, federated_split, make_classification,
)
