from repro.data.synthetic import (  # noqa: F401
    TokenStream, federated_split, make_classification,
)
