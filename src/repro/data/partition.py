"""Data-partitioning subsystem: how M edge devices see the training set.

The paper's §VI experiments use two splits — uniform IID and a label-skew
protocol where every device holds samples from exactly two classes — and
its headline robustness claim is that A-DSGD degrades *less* than D-DSGD
when the data distribution is biased.  This module makes the bias a
measurable knob with three partitioners behind one entry point,
:func:`make_partition`:

``iid``
    Each device draws B samples uniformly without replacement (paper §VI).

``label_shards``
    The deterministic generalisation of the paper's two-class protocol:
    the label space is cut into ``m * shards_per_device`` single-class
    shards organised in *shard groups* — a group is a set of shards that
    covers every class exactly once (requires ``m * shards_per_device`` to
    be a multiple of ``n_classes``).  Devices receive
    ``shards_per_device`` shards each, so with ``shards_per_device=2``
    every device holds exactly two classes, matching the paper.

``dirichlet``
    The standard federated-learning bias knob (Hsu et al., arXiv:1909.06335):
    device m draws its class proportions ``p_m ~ Dirichlet(beta * 1)``.
    ``beta -> inf`` recovers the IID class marginals; ``beta -> 0``
    collapses each device onto a single class.  This is the axis swept by
    ``benchmarks/fig8_bias.py``.

:func:`label_bias` quantifies any split: the mean total-variation distance
between the per-device label histograms and the global histogram (0 = IID
marginals, -> (C-1)/C as devices collapse to one class).

Everything is host-side numpy (partitioning happens once, before the
compiled engine runs) and deterministic given ``seed``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

PARTITION_KINDS = ("iid", "label_shards", "dirichlet")


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# IID
# ---------------------------------------------------------------------------


def partition_iid(y: np.ndarray, m: int, b: int, seed: int = 0) -> np.ndarray:
    """(m, b) sample indices, drawn uniformly without replacement."""
    if m * b > len(y):
        raise ValueError(f"cannot place {m}x{b} samples from {len(y)}")
    return _rng(seed).choice(len(y), (m, b), replace=False)


# ---------------------------------------------------------------------------
# label shards (the paper's non-IID protocol, generalised)
# ---------------------------------------------------------------------------


def label_shard_assignment(m: int, shards_per_device: int, n_classes: int,
                           seed: int = 0) -> np.ndarray:
    """(m, shards_per_device) class ids — which classes each device holds.

    The ``m * shards_per_device`` shards form shard groups of ``n_classes``
    shards; each full group covers every class exactly once, so globally
    each class appears in exactly ``total // n_classes`` (+- 1) shards.
    When the shard count is not a multiple of ``n_classes``, the remainder
    group covers a random class subset (no repeats within the group).

    Shards are dealt so every device's classes are **distinct** (the paper
    protocol: exactly two classes per device at ``shards_per_device=2``):
    each device takes the ``shards_per_device`` classes with the most
    undealt shards, random ties — the max-remaining-first rule keeps class
    counts balanced, so no device is ever forced into a repeat (possible
    only in the degenerate ``shards_per_device > n_classes`` case, where
    repeats are unavoidable and allowed).
    """
    total = m * shards_per_device
    rng = _rng(seed)
    g, rem = divmod(total, n_classes)
    counts = np.full(n_classes, g, np.int64)
    if rem:
        counts[rng.choice(n_classes, rem, replace=False)] += 1
    assign = np.empty((m, shards_per_device), np.int64)
    for dev in rng.permutation(m):
        # distinct classes, most-undealt-shards first (random tie-break)
        priority = np.where(counts > 0, counts + rng.random(n_classes),
                            -np.inf)
        take = np.argsort(-priority)[:shards_per_device]
        take = take[counts[take] > 0]
        if len(take) < shards_per_device:      # degenerate: spd > n_classes
            take = np.concatenate([take, rng.choice(
                n_classes, shards_per_device - len(take))])
        counts[take[:shards_per_device]] -= 1
        assign[dev] = rng.permutation(take[:shards_per_device])
    return assign


def partition_label_shards(y: np.ndarray, m: int, b: int,
                           shards_per_device: int = 2, n_classes: int = 0,
                           seed: int = 0) -> np.ndarray:
    """(m, b) indices: device holds b/shards_per_device samples per shard."""
    n_classes = n_classes or int(y.max()) + 1
    assign = label_shard_assignment(m, shards_per_device, n_classes, seed)
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    rng = _rng(seed + 1)
    per = b // shards_per_device
    counts = [per] * (shards_per_device - 1) + [b - per * (shards_per_device - 1)]
    idx = np.empty((m, b), np.int64)
    for dev in range(m):
        off = 0
        for s, c in enumerate(assign[dev]):
            n_take = counts[s]
            pool = by_class[c]
            idx[dev, off:off + n_take] = rng.choice(
                pool, n_take, replace=n_take > len(pool))
            off += n_take
    return idx


# ---------------------------------------------------------------------------
# Dirichlet(beta)
# ---------------------------------------------------------------------------


def partition_dirichlet(y: np.ndarray, m: int, b: int, beta: float,
                        n_classes: int = 0, seed: int = 0) -> np.ndarray:
    """(m, b) indices: device class proportions ~ Dirichlet(beta).

    Samples are drawn from each class pool with replacement only when a
    pool is exhausted (heavy skew at small beta can demand more samples of
    one class than exist).
    """
    if beta <= 0:
        raise ValueError(f"beta must be > 0, got {beta}")
    n_classes = n_classes or int(y.max()) + 1
    rng = _rng(seed)
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    props = rng.dirichlet(np.full(n_classes, beta), size=m)
    idx = np.empty((m, b), np.int64)
    for dev in range(m):
        classes = rng.choice(n_classes, b, p=props[dev])
        counts = np.bincount(classes, minlength=n_classes)
        off = 0
        for c in range(n_classes):
            n_take = int(counts[c])
            if not n_take:
                continue
            pool = by_class[c]
            idx[dev, off:off + n_take] = rng.choice(
                pool, n_take, replace=n_take > len(pool))
            off += n_take
        rng.shuffle(idx[dev])
    return idx


# ---------------------------------------------------------------------------
# unified entry point + bias metric
# ---------------------------------------------------------------------------


def make_partition(x: np.ndarray, y: np.ndarray, m: int, b: int,
                   kind: str = "iid", beta: float = 1.0,
                   shards_per_device: int = 2, n_classes: int = 0,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Split (x, y) into per-device tensors (x_dev (M,B,d), y_dev (M,B))."""
    if kind == "iid":
        idx = partition_iid(y, m, b, seed)
    elif kind == "label_shards":
        idx = partition_label_shards(y, m, b, shards_per_device, n_classes,
                                     seed)
    elif kind == "dirichlet":
        idx = partition_dirichlet(y, m, b, beta, n_classes, seed)
    else:
        raise ValueError(
            f"unknown partition kind {kind!r}; known: {PARTITION_KINDS}")
    return x[idx], y[idx]


# ---------------------------------------------------------------------------
# population-scale shard assignment (repro.population): O(M) arithmetic
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PopulationPartition:
    """Shard assignment for an M-large population as index *arithmetic*.

    The materialising partitioners above build (M, B) index tables — fine
    for M <= a few dozen, impossible at M = 10^5..10^6.  This class stores
    O(N + C) arrays and computes any device's sample rows on demand:

    ``iid``
        a single (N,) permutation ``order``; device m's j-th sample is
        ``order[(m*B + j) mod N]`` — consecutive windows of one shuffled
        epoch, wrapping with replacement across devices once M*B > N (the
        paper's fixed-total-dataset regime: growing M shrinks each
        device's share of the same N samples).

    ``label_shards``
        the paper's protocol by class cycling: global shard
        ``t = m*spd + s`` holds class ``class_perm[t mod C]`` (consecutive
        shards cycle the class list, so each device's spd classes are
        distinct for spd <= C), and the ``u = t div C``-th use of a class
        reads rows ``[u*per, u*per + per)`` of that class's shuffled pool,
        wrapping mod the pool size.

    :meth:`sample_indices` is pure gather/mod arithmetic, so it traces
    under jit — the population engine calls it per round on the (K,)
    cohort and only (K, B) indices ever materialise.
    """

    kind: str
    m: int
    b: int
    n: int
    n_classes: int = 0
    order: Optional[np.ndarray] = None       # (N,) iid sample permutation
    class_perm: Optional[np.ndarray] = None  # (C,) label_shards class cycle
    pools: Optional[np.ndarray] = None       # (C, P) padded per-class pools
    sizes: Optional[np.ndarray] = None       # (C,) true pool sizes
    shards_per_device: int = 0

    def sample_indices(self, devices):
        """(K, B) training-set rows of the given device ids (traceable)."""
        import jax.numpy as jnp

        dev = jnp.asarray(devices).astype(jnp.int32)[:, None]
        j = jnp.arange(self.b, dtype=jnp.int32)[None, :]
        if self.kind == "iid":
            return jnp.asarray(self.order)[(dev * self.b + j) % self.n]
        per = self.b // self.shards_per_device
        t = dev * self.shards_per_device + j // per
        cls = jnp.asarray(self.class_perm)[t % self.n_classes]
        pos = ((t // self.n_classes) * per + j % per) % jnp.asarray(
            self.sizes)[cls]
        return jnp.asarray(self.pools)[cls, pos]

    def device_labels(self, device: int) -> np.ndarray:
        """The distinct classes device ``device`` holds (host helper)."""
        if self.kind == "iid":
            raise ValueError("iid devices have no fixed class set")
        t = device * self.shards_per_device + np.arange(
            self.shards_per_device)
        return np.asarray(self.class_perm)[t % self.n_classes]


def population_partition(y: np.ndarray, m: int, b: int, kind: str = "iid",
                         shards_per_device: int = 2, n_classes: int = 0,
                         seed: int = 0) -> PopulationPartition:
    """Build a :class:`PopulationPartition` in O(N + C) — no (M, B) table.

    ``dirichlet`` is deliberately unsupported at population scale: its
    per-device proportion draws are O(M * C) state with no arithmetic
    shortcut — materialise via :func:`make_partition` for small M instead.
    """
    n = len(y)
    if kind == "iid":
        return PopulationPartition(kind="iid", m=m, b=b, n=n,
                                   order=_rng(seed).permutation(n))
    if kind == "label_shards":
        n_classes = n_classes or int(y.max()) + 1
        if shards_per_device > n_classes:
            raise ValueError(
                f"population label_shards needs shards_per_device <= "
                f"n_classes; got {shards_per_device} > {n_classes}")
        if b % shards_per_device:
            raise ValueError(
                f"population label_shards needs shards_per_device | b; "
                f"got B={b}, spd={shards_per_device}")
        rng = _rng(seed)
        pools_l = [rng.permutation(np.flatnonzero(y == c))
                   for c in range(n_classes)]
        sizes = np.asarray([len(p) for p in pools_l], np.int64)
        if sizes.min() == 0:
            raise ValueError("every class needs at least one sample")
        pools = np.zeros((n_classes, int(sizes.max())), np.int64)
        for c, p in enumerate(pools_l):
            pools[c, :len(p)] = p
        return PopulationPartition(
            kind="label_shards", m=m, b=b, n=n, n_classes=n_classes,
            class_perm=rng.permutation(n_classes), pools=pools, sizes=sizes,
            shards_per_device=shards_per_device)
    raise ValueError(
        f"unknown population partition kind {kind!r}; known: "
        "('iid', 'label_shards')")


def population_label_bias(part: PopulationPartition, y: np.ndarray,
                          devices=None, n_classes: int = 0) -> float:
    """:func:`label_bias` of a population split, from a device subsample.

    Materialises only the sampled devices' label rows (O(K * B)), so the
    bias of an M = 10^5 split is measurable from a few hundred devices —
    consistency under subsampling is pinned by tests/test_partition.py.
    """
    devices = (np.arange(part.m) if devices is None
               else np.asarray(devices))
    idx = np.asarray(part.sample_indices(devices))
    return label_bias(np.asarray(y)[idx], n_classes)


def label_bias(y_dev: np.ndarray, n_classes: int = 0) -> float:
    """Mean total-variation distance device-histogram vs global histogram.

    0 for IID class marginals; approaches (C-1)/C as every device collapses
    onto a single class.  This is the measurable reading of the bias knob:
    ``dirichlet`` beta maps monotonically onto it.
    """
    n_classes = n_classes or int(y_dev.max()) + 1
    global_h = np.bincount(y_dev.reshape(-1), minlength=n_classes).astype(
        np.float64)
    global_h /= global_h.sum()
    tvs = []
    for dev in range(y_dev.shape[0]):
        h = np.bincount(y_dev[dev], minlength=n_classes).astype(np.float64)
        h /= h.sum()
        tvs.append(0.5 * np.abs(h - global_h).sum())
    return float(np.mean(tvs))
