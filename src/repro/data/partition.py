"""Data-partitioning subsystem: how M edge devices see the training set.

The paper's §VI experiments use two splits — uniform IID and a label-skew
protocol where every device holds samples from exactly two classes — and
its headline robustness claim is that A-DSGD degrades *less* than D-DSGD
when the data distribution is biased.  This module makes the bias a
measurable knob with three partitioners behind one entry point,
:func:`make_partition`:

``iid``
    Each device draws B samples uniformly without replacement (paper §VI).

``label_shards``
    The deterministic generalisation of the paper's two-class protocol:
    the label space is cut into ``m * shards_per_device`` single-class
    shards organised in *shard groups* — a group is a set of shards that
    covers every class exactly once (requires ``m * shards_per_device`` to
    be a multiple of ``n_classes``).  Devices receive
    ``shards_per_device`` shards each, so with ``shards_per_device=2``
    every device holds exactly two classes, matching the paper.

``dirichlet``
    The standard federated-learning bias knob (Hsu et al., arXiv:1909.06335):
    device m draws its class proportions ``p_m ~ Dirichlet(beta * 1)``.
    ``beta -> inf`` recovers the IID class marginals; ``beta -> 0``
    collapses each device onto a single class.  This is the axis swept by
    ``benchmarks/fig8_bias.py``.

:func:`label_bias` quantifies any split: the mean total-variation distance
between the per-device label histograms and the global histogram (0 = IID
marginals, -> (C-1)/C as devices collapse to one class).

Everything is host-side numpy (partitioning happens once, before the
compiled engine runs) and deterministic given ``seed``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

PARTITION_KINDS = ("iid", "label_shards", "dirichlet")


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# IID
# ---------------------------------------------------------------------------


def partition_iid(y: np.ndarray, m: int, b: int, seed: int = 0) -> np.ndarray:
    """(m, b) sample indices, drawn uniformly without replacement."""
    if m * b > len(y):
        raise ValueError(f"cannot place {m}x{b} samples from {len(y)}")
    return _rng(seed).choice(len(y), (m, b), replace=False)


# ---------------------------------------------------------------------------
# label shards (the paper's non-IID protocol, generalised)
# ---------------------------------------------------------------------------


def label_shard_assignment(m: int, shards_per_device: int, n_classes: int,
                           seed: int = 0) -> np.ndarray:
    """(m, shards_per_device) class ids — which classes each device holds.

    The ``m * shards_per_device`` shards form shard groups of ``n_classes``
    shards; each full group covers every class exactly once, so globally
    each class appears in exactly ``total // n_classes`` (+- 1) shards.
    When the shard count is not a multiple of ``n_classes``, the remainder
    group covers a random class subset (no repeats within the group).

    Shards are dealt so every device's classes are **distinct** (the paper
    protocol: exactly two classes per device at ``shards_per_device=2``):
    each device takes the ``shards_per_device`` classes with the most
    undealt shards, random ties — the max-remaining-first rule keeps class
    counts balanced, so no device is ever forced into a repeat (possible
    only in the degenerate ``shards_per_device > n_classes`` case, where
    repeats are unavoidable and allowed).
    """
    total = m * shards_per_device
    rng = _rng(seed)
    g, rem = divmod(total, n_classes)
    counts = np.full(n_classes, g, np.int64)
    if rem:
        counts[rng.choice(n_classes, rem, replace=False)] += 1
    assign = np.empty((m, shards_per_device), np.int64)
    for dev in rng.permutation(m):
        # distinct classes, most-undealt-shards first (random tie-break)
        priority = np.where(counts > 0, counts + rng.random(n_classes),
                            -np.inf)
        take = np.argsort(-priority)[:shards_per_device]
        take = take[counts[take] > 0]
        if len(take) < shards_per_device:      # degenerate: spd > n_classes
            take = np.concatenate([take, rng.choice(
                n_classes, shards_per_device - len(take))])
        counts[take[:shards_per_device]] -= 1
        assign[dev] = rng.permutation(take[:shards_per_device])
    return assign


def partition_label_shards(y: np.ndarray, m: int, b: int,
                           shards_per_device: int = 2, n_classes: int = 0,
                           seed: int = 0) -> np.ndarray:
    """(m, b) indices: device holds b/shards_per_device samples per shard."""
    n_classes = n_classes or int(y.max()) + 1
    assign = label_shard_assignment(m, shards_per_device, n_classes, seed)
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    rng = _rng(seed + 1)
    per = b // shards_per_device
    counts = [per] * (shards_per_device - 1) + [b - per * (shards_per_device - 1)]
    idx = np.empty((m, b), np.int64)
    for dev in range(m):
        off = 0
        for s, c in enumerate(assign[dev]):
            n_take = counts[s]
            pool = by_class[c]
            idx[dev, off:off + n_take] = rng.choice(
                pool, n_take, replace=n_take > len(pool))
            off += n_take
    return idx


# ---------------------------------------------------------------------------
# Dirichlet(beta)
# ---------------------------------------------------------------------------


def partition_dirichlet(y: np.ndarray, m: int, b: int, beta: float,
                        n_classes: int = 0, seed: int = 0) -> np.ndarray:
    """(m, b) indices: device class proportions ~ Dirichlet(beta).

    Samples are drawn from each class pool with replacement only when a
    pool is exhausted (heavy skew at small beta can demand more samples of
    one class than exist).
    """
    if beta <= 0:
        raise ValueError(f"beta must be > 0, got {beta}")
    n_classes = n_classes or int(y.max()) + 1
    rng = _rng(seed)
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    props = rng.dirichlet(np.full(n_classes, beta), size=m)
    idx = np.empty((m, b), np.int64)
    for dev in range(m):
        classes = rng.choice(n_classes, b, p=props[dev])
        counts = np.bincount(classes, minlength=n_classes)
        off = 0
        for c in range(n_classes):
            n_take = int(counts[c])
            if not n_take:
                continue
            pool = by_class[c]
            idx[dev, off:off + n_take] = rng.choice(
                pool, n_take, replace=n_take > len(pool))
            off += n_take
        rng.shuffle(idx[dev])
    return idx


# ---------------------------------------------------------------------------
# unified entry point + bias metric
# ---------------------------------------------------------------------------


def make_partition(x: np.ndarray, y: np.ndarray, m: int, b: int,
                   kind: str = "iid", beta: float = 1.0,
                   shards_per_device: int = 2, n_classes: int = 0,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Split (x, y) into per-device tensors (x_dev (M,B,d), y_dev (M,B))."""
    if kind == "iid":
        idx = partition_iid(y, m, b, seed)
    elif kind == "label_shards":
        idx = partition_label_shards(y, m, b, shards_per_device, n_classes,
                                     seed)
    elif kind == "dirichlet":
        idx = partition_dirichlet(y, m, b, beta, n_classes, seed)
    else:
        raise ValueError(
            f"unknown partition kind {kind!r}; known: {PARTITION_KINDS}")
    return x[idx], y[idx]


def label_bias(y_dev: np.ndarray, n_classes: int = 0) -> float:
    """Mean total-variation distance device-histogram vs global histogram.

    0 for IID class marginals; approaches (C-1)/C as every device collapses
    onto a single class.  This is the measurable reading of the bias knob:
    ``dirichlet`` beta maps monotonically onto it.
    """
    n_classes = n_classes or int(y_dev.max()) + 1
    global_h = np.bincount(y_dev.reshape(-1), minlength=n_classes).astype(
        np.float64)
    global_h /= global_h.sum()
    tvs = []
    for dev in range(y_dev.shape[0]):
        h = np.bincount(y_dev[dev], minlength=n_classes).astype(np.float64)
        h /= h.sum()
        tvs.append(0.5 * np.abs(h - global_h).sum())
    return float(np.mean(tvs))
