"""repro: over-the-air distributed SGD (A-DSGD / D-DSGD) as a JAX framework.

Reproduction of Amiri & Gunduz, "Machine Learning at the Wireless Edge:
Distributed Stochastic Gradient Descent Over-the-Air" (IEEE TSP 2020),
plus a multi-architecture distributed training/serving substrate.
"""
__version__ = "1.0.0"
