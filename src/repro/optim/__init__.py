from repro.optim.optim import Optimizer, make_optimizer  # noqa: F401
