"""Optimizers (pure pytree transforms): SGD, momentum, Adam; warmup-cosine LR.

No optax in this environment — implemented from the definitions.  Adam is the
paper's §VI choice; the PS applies the optimizer to the *reconstructed*
average gradient ghat (paper eq. `theta <- theta - eta ghat` generalises to
any first-order update on ghat).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class Optimizer:
    name: str = "adam"
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    momentum: float = 0.9
    weight_decay: float = 0.0
    warmup_steps: int = 0
    total_steps: int = 0  # 0 => constant LR after warmup
    grad_clip: float = 0.0

    # ------------------------------------------------------------------ state
    def init(self, params: Params) -> Params:
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)  # noqa: E731
        if self.name == "adam":
            return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}
        if self.name == "momentum":
            return {"m": zeros(), "count": jnp.zeros((), jnp.int32)}
        if self.name == "sgd":
            return {"count": jnp.zeros((), jnp.int32)}
        raise ValueError(self.name)

    # --------------------------------------------------------------- schedule
    def lr_at(self, step) -> jnp.ndarray:
        step = jnp.asarray(step, jnp.float32)
        lr = jnp.asarray(self.lr, jnp.float32)
        if self.warmup_steps > 0:
            warm = jnp.minimum(step / self.warmup_steps, 1.0)
        else:
            warm = 1.0
        if self.total_steps > 0:
            span = max(self.total_steps - self.warmup_steps, 1)
            frac = jnp.clip((step - self.warmup_steps) / span, 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            cos = 1.0
        return lr * warm * cos

    # ------------------------------------------------------------------ apply
    def apply(
        self, params: Params, grads: Params, state: Params
    ) -> Tuple[Params, Params]:
        if self.grad_clip > 0:
            leaves = jax.tree.leaves(grads)
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
            gn = jnp.sqrt(sq)
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state["count"] + 1
        lr = self.lr_at(state["count"])
        wd = self.weight_decay

        if self.name == "adam":
            b1, b2 = self.b1, self.b2
            m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
            v = jax.tree.map(
                lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
            )
            c = count.astype(jnp.float32)
            mhat_s = 1.0 / (1 - b1**c)
            vhat_s = 1.0 / (1 - b2**c)

            def upd(p, m_, v_):
                step_ = m_ * mhat_s / (jnp.sqrt(v_ * vhat_s) + self.eps)
                return p - lr * (step_ + wd * p)

            new_params = jax.tree.map(upd, params, m, v)
            return new_params, {"m": m, "v": v, "count": count}
        if self.name == "momentum":
            m = jax.tree.map(lambda m_, g: self.momentum * m_ + g, state["m"], grads)
            new_params = jax.tree.map(lambda p, m_: p - lr * (m_ + wd * p), params, m)
            return new_params, {"m": m, "count": count}
        if self.name == "sgd":
            new_params = jax.tree.map(lambda p, g: p - lr * (g + wd * p), params, grads)
            return new_params, {"count": count}
        raise ValueError(self.name)


def make_optimizer(train_cfg) -> Optimizer:
    return Optimizer(
        name=train_cfg.optimizer,
        lr=train_cfg.lr,
        weight_decay=train_cfg.weight_decay,
        warmup_steps=train_cfg.warmup_steps,
        total_steps=train_cfg.total_steps,
        grad_clip=train_cfg.grad_clip,
    )
