"""Public model API: init / train forward (loss) / serve decode step.

``Batch`` covers all modalities:
  tokens    (B, L)  int32        — always present (labels = tokens shifted)
  positions (B, L[,3]) int32     — optional (M-RoPE needs 3-D)
  extra     (B, P, D) float      — stub frontend embeddings (vlm)
  frames    (B, F, D_enc) float  — stub audio frames (whisper encoder input)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer

Params = Dict[str, Any]


def init_params(cfg: ArchConfig, key) -> Params:
    return transformer.init_params(cfg, key)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            compute_dtype=jnp.bfloat16, remat: bool = True,
            aux_weight: float = 0.01,
            loss_chunk: int = 0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics).

    loss_chunk > 0 computes the vocab head + CE over token chunks (scan) so
    the (tokens, vocab) logits tensor is never materialised at once — needed
    at framework scale when the vocab does not shard evenly.
    """
    tokens = batch["tokens"]
    enc_out = None
    if cfg.encoder is not None:
        enc_out = transformer.encode_audio(
            params, cfg, batch["frames"].astype(compute_dtype))
    hidden, _, aux = transformer.forward(
        params, cfg, tokens,
        positions=batch.get("positions"),
        extra_embeds=batch.get("extra"),
        enc_out=enc_out,
        compute_dtype=compute_dtype, remat=remat, return_hidden=True)
    # predict token t+1 from prefix; modality prefixes are unsupervised
    P = hidden.shape[1] - tokens.shape[1]
    h = hidden[:, P:, :][:, :-1, :]
    tgt = tokens[:, 1:]
    w_head = (params["embed"].T if cfg.tie_embeddings
              else params["lm_head"])

    def chunk_nll(hc, tc):
        lg = (hc @ w_head.astype(hc.dtype)).astype(jnp.float32)
        logp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]

    B, Lm1, D = h.shape
    n_tok = B * Lm1
    if loss_chunk and n_tok > loss_chunk:
        ck = loss_chunk
        while n_tok % ck:
            ck -= 1
        hf = h.reshape(n_tok // ck, ck, D)
        tf = tgt.reshape(n_tok // ck, ck)
        nll_sum = jax.lax.scan(
            lambda acc, xs: (acc + jnp.sum(chunk_nll(*xs)), None),
            jnp.zeros((), jnp.float32), (hf, tf))[0]
        loss = nll_sum / n_tok
    else:
        loss = jnp.mean(chunk_nll(h, tgt))
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl": jnp.exp(jnp.clip(loss, 0, 20.0))}


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16,
                      decode_window: Optional[int] = None) -> Params:
    return transformer.init_cache(cfg, batch, max_len, dtype, decode_window)


def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                cache: Params, pos, *,
                enc_out: Optional[jnp.ndarray] = None,
                compute_dtype=jnp.bfloat16,
                decode_window: Optional[int] = None):
    """One-token decode. token: (B, 1) int32; pos: scalar current position.

    Returns (logits (B, 1, V), new_cache).  ``cache_index`` is pos for full
    caches, pos % window for ring-buffer (sliding-window) caches.
    """
    C = None
    if decode_window is not None:
        C = decode_window
        cache_index = jnp.asarray(pos) % C
    else:
        cache_index = jnp.asarray(pos)
    B = token.shape[0]
    pos1 = jnp.full((B, 1), pos, jnp.int32)
    positions = (jnp.repeat(pos1[..., None], 3, axis=-1)
                 if cfg.mrope_sections is not None else pos1)
    logits, new_cache, _ = transformer.forward(
        params, cfg, token, positions=positions, enc_out=enc_out,
        cache=cache, cache_index=cache_index, compute_dtype=compute_dtype,
        remat=False, decode_window=decode_window)
    return logits, new_cache


def param_count(params: Params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))
