"""Shared model layers: norms, RoPE / M-RoPE, GQA attention (train/prefill/
decode with KV cache, optional sliding window and qk-norm), SwiGLU MLP.

Conventions: params are nested dicts of jnp arrays; every ``init_*`` gets a
PRNG key; every ``apply`` is a pure function.  Activations may be bf16; all
softmax/norm math is fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dense_init(key, in_dim, out_dim, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim),
                                        jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"w": jnp.ones((d,), jnp.float32)}


def rms_norm(x: jnp.ndarray, p: Params, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["w"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layer_norm(x: jnp.ndarray, p: Params, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, L, H, Dh); positions: (B, L) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, L, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions3: (B, L, 3) = (t, h, w) ids.

    The head_dim/2 frequency slots are split into |sections| groups; group i
    rotates by positions3[..., i] (arXiv:2409.12191 §2.1).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)               # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :],
                         positions3.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1)                                                # (B, L, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    causal: bool = True
    mrope_sections: Optional[Tuple[int, int, int]] = None
    norm_eps: float = 1e-5


def init_attention(key, spec: AttnSpec) -> Params:
    ks = jax.random.split(key, 4)
    d, h = spec.d_model, spec.head_dim
    p = {
        "wq": _dense_init(ks[0], d, spec.n_heads * h),
        "wk": _dense_init(ks[1], d, spec.n_kv_heads * h),
        "wv": _dense_init(ks[2], d, spec.n_kv_heads * h),
        "wo": _dense_init(ks[3], spec.n_heads * h, d),
    }
    if spec.qk_norm:
        p["q_norm"] = init_rmsnorm(h)
        p["k_norm"] = init_rmsnorm(h)
    return p


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int], k_valid: Optional[jnp.ndarray] = None):
    """(B, 1, Lq, Lk) additive bias in fp32."""
    diff = q_pos[:, :, None] - k_pos[:, None, :]        # (B, Lq, Lk)
    ok = jnp.ones_like(diff, dtype=bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, -1e30)[:, None, :, :].astype(jnp.float32)


def attention(p: Params, spec: AttnSpec, x: jnp.ndarray,
              positions: jnp.ndarray,
              kv_cache: Optional[Params] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_source: Optional[jnp.ndarray] = None,
              kv_positions: Optional[jnp.ndarray] = None):
    """GQA attention.

    x: (B, L, D).  positions: (B, L) (or (B, L, 3) for M-RoPE).
    kv_cache: {"k","v"} of (B, C, Hkv, Dh) — decode mode: new K/V written at
      ``cache_index`` (B,)-or-scalar slot, attention runs over the cache.
    kv_source: cross-attention source (B, Lsrc, D) (whisper decoder).
    Returns (out, new_kv_cache|None).
    """
    B, L, _ = x.shape
    h, hq, hkv = spec.head_dim, spec.n_heads, spec.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, L, hq, h)
    src = kv_source if kv_source is not None else x
    k = (src @ p["wk"].astype(x.dtype)).reshape(B, src.shape[1], hkv, h)
    v = (src @ p["wv"].astype(x.dtype)).reshape(B, src.shape[1], hkv, h)
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"], spec.norm_eps)
        k = rms_norm(k, p["k_norm"], spec.norm_eps)
    use_rope = kv_source is None  # no rope on cross-attention
    if use_rope:
        if spec.mrope_sections is not None:
            q = apply_mrope(q, positions, spec.rope_theta, spec.mrope_sections)
            kpos = kv_positions if kv_positions is not None else positions
            k = apply_mrope(k, kpos, spec.rope_theta, spec.mrope_sections)
            q_pos1 = positions[..., 0]
        else:
            q = apply_rope(q, positions, spec.rope_theta)
            kpos = kv_positions if kv_positions is not None else positions
            k = apply_rope(k, kpos, spec.rope_theta)
            q_pos1 = positions
    else:
        q_pos1 = positions if positions.ndim == 2 else positions[..., 0]

    new_cache = None
    if kv_cache is not None:
        # decode: write the L new entries (L=1 for decode) at the cache slot.
        # The cache stores absolute positions ("pos", init -1) so both full
        # and ring-buffer (sliding-window) caches share one mask rule.
        idx = jnp.asarray(cache_index)
        ck, cv, cpos = kv_cache["k"], kv_cache["v"], kv_cache["pos"]
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 idx, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cpos, q_pos1.astype(cpos.dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        k_valid = cpos >= 0
        bias = _mask_bias(q_pos1, cpos, spec.causal, spec.sliding_window,
                          k_valid)
    else:
        k_pos = (kv_positions if kv_positions is not None else q_pos1)
        if kv_source is not None:
            k_pos = jnp.broadcast_to(
                jnp.arange(src.shape[1])[None, :], (B, src.shape[1]))
            bias = _mask_bias(q_pos1, k_pos, False, None)
        else:
            bias = _mask_bias(q_pos1, k_pos, spec.causal, spec.sliding_window)

    # grouped heads: fold group dim into q
    groups = hq // hkv
    qg = q.reshape(B, L, hkv, groups, h)
    scores = jnp.einsum("blkgh,bmkh->bklgm", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(h))
    scores = scores + bias[:, 0][:, None, :, None, :]   # (B,hkv,L,g,M)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bklgm,bmkh->blkgh", probs, v)
    out = out.reshape(B, L, hq * h)
    return out @ p["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": _dense_init(k1, d, d_ff),
            "w_up": _dense_init(k2, d, d_ff),
            "w_down": _dense_init(k3, d_ff, d)}


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


def init_gelu_mlp(key, d: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {"w_in": _dense_init(k1, d, d_ff),
            "b_in": jnp.zeros((d_ff,), jnp.float32),
            "w_out": _dense_init(k2, d_ff, d),
            "b_out": jnp.zeros((d,), jnp.float32)}


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype))
    return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
