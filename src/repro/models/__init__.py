from repro.models.model import (  # noqa: F401
    decode_step, init_decode_cache, init_params, loss_fn, param_count,
)
