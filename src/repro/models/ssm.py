"""Mamba2 (SSD) mixer block — chunked scan for train/prefill, O(1) decode.

Follows the minimal SSD formulation (Dao & Gu 2024): per-head scalar decay
a_t = exp(dt_t * A_head), shared (n_groups=1) B/C of size d_state, depthwise
causal conv on the SSM input, gated output.  The chunked algorithm computes
intra-chunk contributions with a lower-triangular decay-weighted "attention"
and carries the (H, hd, N) state across chunks with a lax.scan — compile time
is flat in sequence length and the state shards over heads ('model' axis).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import _dense_init, init_rmsnorm, rms_norm

Params = Dict[str, jnp.ndarray]


def init_mamba2(key, d_model: int, cfg: SSMConfig) -> Params:
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    ks = jax.random.split(key, 8)
    return {
        # per-output input projections [z, x, B, C, dt]: the fused variant's
        # split boundaries (7168/14336/14400/...) cannot align with a 16-way
        # output sharding, which made GSPMD re-lay the whole activation per
        # layer (measured: 105 GB/dev of all-gathers on zamba2 — §Perf it.4);
        # separate matrices shard independently and split nothing.
        "w_z": _dense_init(ks[0], d_model, d_in),
        "w_x": _dense_init(ks[1], d_model, d_in),
        "w_b": _dense_init(ks[3], d_model, cfg.d_state),
        "w_c": _dense_init(ks[4], d_model, cfg.d_state),
        "w_dt": _dense_init(ks[5], d_model, n_heads),
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, d_in),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": init_rmsnorm(d_in),
        "w_out": _dense_init(ks[6], d_in, d_model),
    }


def _split_proj(p, x, d_in, d_state, n_heads):
    z = x @ p["w_z"].astype(x.dtype)
    xs = x @ p["w_x"].astype(x.dtype)
    b = x @ p["w_b"].astype(x.dtype)
    c = x @ p["w_c"].astype(x.dtype)
    dt = x @ p["w_dt"].astype(x.dtype)
    return z, xs, b, c, dt


def _causal_conv(xs, conv_w, conv_b, state=None):
    """Depthwise causal conv. xs: (B, L, d_in); state: (B, W-1, d_in)."""
    W = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros(xs.shape[:1] + (W - 1,) + xs.shape[2:], xs.dtype)
    else:
        pad = state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)            # (B, L+W-1, d_in)
    out = sum(xp[:, i:i + xs.shape[1]] * conv_w[i].astype(xs.dtype)
              for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad[:, :0]
    return jax.nn.silu(out + conv_b.astype(xs.dtype)), new_state


def mamba2_forward(p: Params, x: jnp.ndarray, d_model: int, cfg: SSMConfig,
                   state: Optional[Params] = None
                   ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, L, D). state (decode): {"ssm": (B,H,hd,N), "conv": (B,W-1,d_in)}.

    Training/prefill: state is None -> chunked scan from zero state.
    Decode: L == 1 single-step recurrence; returns the updated state.
    """
    B, L, _ = x.shape
    d_in = cfg.expand * d_model
    hd, N = cfg.head_dim, cfg.d_state
    H = d_in // hd
    z, xs, b, c, dt = _split_proj(p, x, d_in, N, H)
    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])               # (B, L, H)
    a = -jnp.exp(p["a_log"])                           # (H,) negative
    decay = jnp.exp(dt * a)                            # (B, L, H) in (0,1)
    xh = xs.reshape(B, L, H, hd).astype(jnp.float32)
    bf = b.astype(jnp.float32)                          # (B, L, N)
    cf = c.astype(jnp.float32)                          # (B, L, N)

    if state is not None and L == 1:
        # single-step: h' = decay * h + dt * x  outer  B ; y = C . h'
        h0 = state["ssm"].astype(jnp.float32)           # (B,H,hd,N)
        dtx = dt[:, 0, :, None] * xh[:, 0]              # (B,H,hd)
        h1 = decay[:, 0, :, None, None] * h0 + dtx[..., None] * bf[:, 0, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h1, cf[:, 0])[:, None]   # (B,1,H,hd)
        y = y + p["d_skip"][None, None, :, None] * xh
        new_state = {"ssm": h1.astype(state["ssm"].dtype), "conv": new_conv}
    else:
        Q = min(cfg.chunk, L)
        while L % Q:
            Q -= 1
        nC = L // Q
        # reshape into chunks
        dtc = dt.reshape(B, nC, Q, H)
        dec = decay.reshape(B, nC, Q, H)
        xc = xh.reshape(B, nC, Q, H, hd)
        bc = bf.reshape(B, nC, Q, N)
        cc = cf.reshape(B, nC, Q, N)
        logdec = jnp.log(jnp.maximum(dec, 1e-20))
        cum = jnp.cumsum(logdec, axis=2)                # (B,nC,Q,H)
        # intra-chunk: y_t = sum_{s<=t} C_t.B_s dt_s x_s * exp(cum_t - cum_s)
        rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nC,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
        # mask BEFORE exp: exp of masked (t<s) entries can overflow and the
        # where-gradient would turn inf * 0 into NaN
        gate = jnp.exp(jnp.where(tri, rel, -1e30))
        cb = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)            # (B,nC,Q,Q)
        w = cb[..., None] * gate * dtc[:, :, None, :, :]      # (B,nC,Q,Q,H)
        y_intra = jnp.einsum("bcqsh,bcshd->bcqhd", w, xc)
        # inter-chunk: carry state across chunks
        # state update: h' = (prod decay) h + sum_s exp(cum_Q - cum_s) dt_s x_s B_s
        tail = cum[:, :, -1:, :] - cum                        # (B,nC,Q,H)
        wx = jnp.exp(tail)[..., None] * (dtc[..., None] * xc)  # (B,nC,Q,H,hd)
        dS = jnp.einsum("bcqhd,bcqn->bchdn", wx, bc)           # (B,nC,H,hd,N)
        chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nC,H)

        def scan_body(h, inp):
            dS_c, cd_c = inp
            h_new = cd_c[..., None, None] * h + dS_c
            return h_new, h

        h0 = (state["ssm"].astype(jnp.float32) if state is not None
              else jnp.zeros((B, H, hd, N), jnp.float32))
        h_fin, h_prev = jax.lax.scan(
            scan_body, h0,
            (dS.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
        h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # (B,nC,H,hd,N)
        yin = jnp.einsum("bcqn,bchdn->bcqhd", cc, h_prev)      # (B,nC,Q,H,hd)
        # the carried state decays by exp(cum_t) (chunk start -> t, per head)
        yin = yin * jnp.exp(cum)[..., None]
        y = (y_intra + yin).reshape(B, L, H, hd)
        y = y + p["d_skip"][None, None, :, None] * xh
        new_state = None
        if state is not None:
            new_state = {"ssm": h_fin.astype(state["ssm"].dtype),
                         "conv": new_conv}

    y = (y * jax.nn.silu(z.reshape(B, L, H, hd).astype(jnp.float32)))
    y = y.reshape(B, L, d_in)
    y = rms_norm(y.astype(x.dtype), p["out_norm"])
    return y @ p["w_out"].astype(x.dtype), new_state


def init_mamba2_state(cfg: SSMConfig, d_model: int, batch: int,
                      dtype=jnp.float32) -> Params:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return {"ssm": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), dtype),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype)}
