"""Model assembly: scanned decoder stacks for all assigned families.

Stacks are homogeneous per architecture (dense GQA / MoE / Mamba2 / RWKV6),
so layers are lax.scan'ed over stacked params — compile time flat in depth.
Zamba2's hybrid layout is 13 super-blocks of (6 scanned Mamba2 layers + one
application of the weight-SHARED attention block) + trailing Mamba2 layers.
Whisper adds a bidirectional encoder and per-decoder-layer cross-attention.
Qwen2-VL consumes stub patch embeddings (prefix) and M-RoPE positions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA2, MOE, RWKV6, SWA, ArchConfig
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (AttnSpec, attention, gelu_mlp, init_attention,
                                 init_gelu_mlp, init_rmsnorm, init_swiglu,
                                 rms_norm, swiglu, _dense_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def attn_spec(cfg: ArchConfig, sliding: bool = False,
              decode_window: Optional[int] = None,
              causal: bool = True) -> AttnSpec:
    window = cfg.sliding_window if sliding else None
    if decode_window is not None:
        window = decode_window
    return AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                    qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                    sliding_window=window, causal=causal,
                    mrope_sections=cfg.mrope_sections, norm_eps=cfg.norm_eps)


def block_kind(cfg: ArchConfig) -> str:
    kinds = set(cfg.blocks())
    assert len(kinds) == 1, f"heterogeneous stack unsupported: {kinds}"
    return next(iter(kinds))


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind in (ATTN, SWA):
        p = {"ln1": init_rmsnorm(d), "ln2": init_rmsnorm(d),
             "attn": init_attention(ks[0], attn_spec(cfg))}
        if cfg.family == "audio":
            p["mlp"] = init_gelu_mlp(ks[1], d, cfg.d_ff)
            p["ln_x"] = init_rmsnorm(d)
            p["xattn"] = init_attention(ks[2], attn_spec(cfg, causal=False))
        else:
            p["mlp"] = init_swiglu(ks[1], d, cfg.d_ff)
        return p
    if kind == MOE:
        return {"ln1": init_rmsnorm(d), "ln2": init_rmsnorm(d),
                "attn": init_attention(ks[0], attn_spec(cfg)),
                "moe": moe_lib.init_moe(ks[1], d, cfg.moe)}
    if kind == MAMBA2:
        return {"ln1": init_rmsnorm(d),
                "mamba": ssm_lib.init_mamba2(ks[0], d, cfg.ssm)}
    if kind == RWKV6:
        return {"ln1": init_rmsnorm(d), "ln2": init_rmsnorm(d),
                "time": rwkv_lib.init_rwkv6_time(ks[0], d, cfg.rwkv),
                "channel": rwkv_lib.init_rwkv6_channel(ks[1], d, cfg.d_ff)}
    raise ValueError(kind)


def init_params(cfg: ArchConfig, key) -> Params:
    kind = block_kind(cfg)
    k_embed, k_blocks, k_head, k_shared, k_enc = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda kk: init_layer(kk, cfg, kind))(layer_keys)
    params: Params = {
        "embed": jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(k_head, cfg.d_model, cfg.vocab)
    if cfg.shared_attn_every:
        ks1, ks2 = jax.random.split(k_shared)
        params["shared_attn"] = {
            "ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks1, attn_spec(cfg)),
            "mlp": init_swiglu(ks2, cfg.d_model, cfg.d_ff)}
    if cfg.encoder is not None:
        e = cfg.encoder
        ek = jax.random.split(k_enc, e.n_layers + 1)
        espec = AttnSpec(d_model=e.d_model, n_heads=e.n_heads,
                         n_kv_heads=e.n_heads, head_dim=e.d_model // e.n_heads,
                         causal=False)

        def enc_layer(kk):
            a, b = jax.random.split(kk)
            return {"ln1": init_rmsnorm(e.d_model), "ln2": init_rmsnorm(e.d_model),
                    "attn": init_attention(a, espec),
                    "mlp": init_gelu_mlp(b, e.d_model, e.d_ff)}

        params["encoder"] = {
            "blocks": jax.vmap(enc_layer)(ek[:-1]),
            "final_norm": init_rmsnorm(e.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def apply_block(p: Params, cfg: ArchConfig, kind: str, x: jnp.ndarray,
                positions, cache=None, cache_index=None, enc_out=None,
                decode_window: Optional[int] = None):
    """One decoder block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in (ATTN, SWA, MOE):
        spec = attn_spec(cfg, sliding=(kind == SWA or cfg.sliding_window
                                       is not None),
                         decode_window=decode_window)
        h, kv = attention(p["attn"], spec, rms_norm(x, p["ln1"], cfg.norm_eps),
                          positions,
                          kv_cache=None if cache is None else cache["kv"],
                          cache_index=cache_index)
        x = x + h
        if enc_out is not None:   # whisper decoder cross-attention
            hx, _ = attention(p["xattn"], attn_spec(cfg, causal=False),
                              rms_norm(x, p["ln_x"], cfg.norm_eps),
                              positions, kv_source=enc_out)
            x = x + hx
        h2_in = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == MOE:
            h2, aux = moe_lib.moe_mlp(p["moe"], h2_in, cfg.moe)
        elif cfg.family == "audio":
            h2 = gelu_mlp(p["mlp"], h2_in)
        else:
            h2 = swiglu(p["mlp"], h2_in)
        x = x + h2
        if cache is not None:
            new_cache = dict(cache)
            new_cache["kv"] = kv
        return x, new_cache, aux
    if kind == MAMBA2:
        h, st = ssm_lib.mamba2_forward(
            p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg.d_model,
            cfg.ssm, None if cache is None else cache["ssm_state"])
        x = x + h
        if cache is not None:
            new_cache = dict(cache)
            new_cache["ssm_state"] = st
        return x, new_cache, aux
    if kind == RWKV6:
        st_t = None if cache is None else cache["rwkv"]["time"]
        st_c = None if cache is None else cache["rwkv"]["channel"]
        h, st_t2 = rwkv_lib.rwkv6_time_mix(
            p["time"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg.rwkv, st_t)
        x = x + h
        h2, st_c2 = rwkv_lib.rwkv6_channel_mix(
            p["channel"], rms_norm(x, p["ln2"], cfg.norm_eps), st_c)
        x = x + h2
        if cache is not None:
            new_cache = {"rwkv": {"time": st_t2, "channel": st_c2}}
        return x, new_cache, aux
    raise ValueError(kind)


def _apply_shared_attn(p: Params, cfg: ArchConfig, x, positions,
                       cache=None, cache_index=None,
                       decode_window: Optional[int] = None):
    spec = attn_spec(cfg, decode_window=decode_window)
    h, kv = attention(p["attn"], spec, rms_norm(x, p["ln1"], cfg.norm_eps),
                      positions, kv_cache=cache, cache_index=cache_index)
    x = x + h
    x = x + swiglu(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, kv


# ---------------------------------------------------------------------------
# whole-model forward
# ---------------------------------------------------------------------------


def encode_audio(params: Params, cfg: ArchConfig, frames: jnp.ndarray):
    """Whisper encoder over stub frame embeddings (B, n_frames, d_enc)."""
    e = cfg.encoder
    espec = AttnSpec(d_model=e.d_model, n_heads=e.n_heads,
                     n_kv_heads=e.n_heads, head_dim=e.d_model // e.n_heads,
                     causal=False)
    B, L, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    x = frames

    def body(x, lp):
        h, _ = attention(lp["attn"], espec, rms_norm(x, lp["ln1"]), pos)
        x = x + h
        x = x + gelu_mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["final_norm"])


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None,
            extra_embeds: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            cache: Optional[Params] = None,
            cache_index=None,
            compute_dtype=jnp.bfloat16,
            remat: bool = False,
            decode_window: Optional[int] = None,
            return_hidden: bool = False):
    """Full forward. Returns (logits|hidden, new_cache, aux_loss).

    tokens: (B, L) int32. extra_embeds: modality prefix (B, P, D) — the stub
    frontend output for vlm; for audio, enc_out is the encoder output fed to
    cross-attention.  cache/cache_index: decode mode.
    """
    kind = block_kind(cfg)
    B, Lt = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(compute_dtype), x], axis=1)
    L = x.shape[1]
    if positions is None:
        pos1 = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
        if cache_index is not None:
            pos1 = pos1 + jnp.asarray(cache_index, jnp.int32)
        if cfg.mrope_sections is not None:
            positions = jnp.repeat(pos1[..., None], 3, axis=-1)
        else:
            positions = pos1

    block_fn = functools.partial(apply_block, cfg=cfg, kind=kind,
                                 cache_index=cache_index, enc_out=enc_out,
                                 decode_window=decode_window)
    _bf = block_fn
    block_fn = lambda p, x, positions, cache: _bf(       # noqa: E731
        p, x=x, positions=positions, cache=cache)
    if remat:
        block_fn = jax.checkpoint(block_fn)

    aux_total = jnp.zeros((), jnp.float32)

    if cfg.shared_attn_every:
        # Zamba2: python loop over super-blocks, scanned mamba segments
        every = cfg.shared_attn_every
        n_shared = cfg.n_layers // every
        x, cache_out, aux_total = _hybrid_stack(
            params, cfg, kind, x, positions, cache, cache_index,
            block_fn, every, n_shared, decode_window)
        out = (rms_norm(x, params["final_norm"], cfg.norm_eps)
               if return_hidden else _head(params, cfg, x))
        return out, cache_out, aux_total

    def scan_body(carry, xs):
        x = carry
        if cache is None:
            lp = xs
            x, _, aux = block_fn(lp, x, positions, None)
            return x, aux
        lp, lcache = xs
        x, new_c, aux = block_fn(lp, x, positions, lcache)
        return x, (new_c, aux)

    if cache is None:
        x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
        new_cache = None
        aux_total = jnp.sum(auxs)
    else:
        x, (new_cache, auxs) = jax.lax.scan(scan_body, x,
                                            (params["blocks"], cache))
        aux_total = jnp.sum(auxs)
    out = (rms_norm(x, params["final_norm"], cfg.norm_eps)
           if return_hidden else _head(params, cfg, x))
    return out, new_cache, aux_total


def _hybrid_stack(params, cfg, kind, x, positions, cache, cache_index,
                  block_fn, every, n_shared, decode_window):
    """Zamba2 layout: [every x mamba, shared-attn] * n_shared + tail mamba."""
    n_layers = cfg.n_layers
    aux_total = jnp.zeros((), jnp.float32)
    mamba_params = params["blocks"]
    shared = params["shared_attn"]
    mcaches = None if cache is None else cache["mamba"]
    acaches = None if cache is None else cache["shared"]
    new_m, new_a = [], []

    def seg_scan(x, seg_params, seg_cache):
        def body(carry, xs):
            x = carry
            if seg_cache is None:
                x, _, aux = block_fn(xs, x, positions, None)
                return x, aux
            lp, lc = xs
            x, nc, aux = block_fn(lp, x, positions, lc)
            return x, (nc, aux)
        if seg_cache is None:
            x, auxs = jax.lax.scan(body, x, seg_params)
            return x, None, jnp.sum(auxs)
        x, (ncache, auxs) = jax.lax.scan(body, x, (seg_params, seg_cache))
        return x, ncache, jnp.sum(auxs)

    idx = 0
    for blk in range(n_shared):
        seg_p = jax.tree.map(lambda a: a[idx:idx + every], mamba_params)
        seg_c = None if mcaches is None else jax.tree.map(
            lambda a: a[idx:idx + every], mcaches)
        x, nc, aux = seg_scan(x, seg_p, seg_c)
        aux_total = aux_total + aux
        if nc is not None:
            new_m.append(nc)
        a_c = None if acaches is None else jax.tree.map(
            lambda a: a[blk], acaches)
        x, na = _apply_shared_attn(shared, cfg, x, positions, a_c,
                                   cache_index, decode_window)
        if na is not None:
            new_a.append(na)
        idx += every
    if idx < n_layers:
        seg_p = jax.tree.map(lambda a: a[idx:], mamba_params)
        seg_c = None if mcaches is None else jax.tree.map(
            lambda a: a[idx:], mcaches)
        x, nc, aux = seg_scan(x, seg_p, seg_c)
        aux_total = aux_total + aux
        if nc is not None:
            new_m.append(nc)
    new_cache = None
    if cache is not None:
        mcat = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m)
        acat = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_a)
        new_cache = {"mamba": mcat, "shared": acat}
    return x, new_cache, aux_total


def _head(params: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16,
               decode_window: Optional[int] = None) -> Params:
    """Build the per-layer decode state stack for one architecture."""
    kind = block_kind(cfg)
    h = cfg.resolved_head_dim
    C = max_len if decode_window is None else min(max_len, decode_window)

    def kv_cache():
        return {"k": jnp.zeros((batch, C, cfg.n_kv_heads, h), dtype),
                "v": jnp.zeros((batch, C, cfg.n_kv_heads, h), dtype),
                "pos": jnp.full((batch, C), -1, jnp.int32)}

    if cfg.shared_attn_every:
        n_shared = cfg.n_layers // cfg.shared_attn_every
        mamba = jax.tree.map(
            lambda a: jnp.stack([a] * cfg.n_layers),
            {"ssm_state": ssm_lib.init_mamba2_state(cfg.ssm, cfg.d_model,
                                                    batch)})
        shared = jax.tree.map(lambda a: jnp.stack([a] * n_shared), kv_cache())
        return {"mamba": mamba, "shared": shared}
    if kind in (ATTN, SWA, MOE):
        return jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers),
                            {"kv": kv_cache()})
    if kind == MAMBA2:
        st = ssm_lib.init_mamba2_state(cfg.ssm, cfg.d_model, batch)
        return jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers),
                            {"ssm_state": st})
    if kind == RWKV6:
        st = rwkv_lib.init_rwkv6_state(cfg.rwkv, cfg.d_model, batch)
        return jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers),
                            {"rwkv": st})
    raise ValueError(kind)
