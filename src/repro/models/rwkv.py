"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Faithful core per arXiv:2404.05892: per-channel token-shift interpolation,
LoRA-parameterised data-dependent decay w_t = exp(-exp(w0 + lora(x))), bonus
u, matrix-valued WKV state S in R^{hd x hd} per head:

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Training/prefill runs a lax.scan over time (state (B,H,hd,hd) shards over
heads / 'model'); decode is the single-step recurrence.  The static
token-shift mix uses per-channel mu (the dynamic ddlerp of the full model is
elided for r/k/v/g — the decay keeps its data-dependence, which is the
paper's headline mechanism).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.models.layers import _dense_init, init_layernorm, layer_norm

Params = Dict[str, jnp.ndarray]


def init_rwkv6_time(key, d: int, cfg: RWKVConfig) -> Params:
    ks = jax.random.split(key, 8)
    hd = cfg.head_dim
    H = d // hd
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),   # r,k,v,g,w
        "w0": jnp.zeros((d,), jnp.float32) - 6.0,
        "w_lora_a": _dense_init(ks[1], d, cfg.decay_lora, scale=0.01),
        "w_lora_b": _dense_init(ks[2], cfg.decay_lora, d, scale=0.01),
        "u": jnp.zeros((H, hd), jnp.float32),
        "wr": _dense_init(ks[3], d, d),
        "wk": _dense_init(ks[4], d, d),
        "wv": _dense_init(ks[5], d, d),
        "wg": _dense_init(ks[6], d, d),
        "wo": _dense_init(ks[7], d, d),
        "ln_x": init_layernorm(d),
    }


def init_rwkv6_channel(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(k1, (2, d), jnp.float32),      # k, r
        "wk": _dense_init(k2, d, d_ff),
        "wv": _dense_init(k3, d_ff, d),
        "wr": _dense_init(jax.random.fold_in(k1, 7), d, d),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]):
    """xx_t = x_{t-1}; prev: (B, 1, D) carried last token (decode) or None."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    xx = jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)
    return xx, x[:, -1:]


def rwkv6_time_mix(p: Params, x: jnp.ndarray, cfg: RWKVConfig,
                   state: Optional[Params] = None
                   ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, L, D). state: {"shift": (B,1,D), "wkv": (B,H,hd,hd)}."""
    B, L, D = x.shape
    hd = cfg.head_dim
    H = D // hd
    xx, last = _token_shift(x, state["shift"] if state else None)
    mu = p["mu"].astype(x.dtype)
    zr = x + (xx - x) * mu[0]
    zk = x + (xx - x) * mu[1]
    zv = x + (xx - x) * mu[2]
    zg = x + (xx - x) * mu[3]
    zw = x + (xx - x) * mu[4]
    r = (zr @ p["wr"].astype(x.dtype)).reshape(B, L, H, hd)
    k = (zk @ p["wk"].astype(x.dtype)).reshape(B, L, H, hd)
    v = (zv @ p["wv"].astype(x.dtype)).reshape(B, L, H, hd)
    g = jax.nn.silu(zg @ p["wg"].astype(x.dtype))
    lora = jnp.tanh(zw @ p["w_lora_a"].astype(x.dtype)) @ \
        p["w_lora_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp((p["w0"] + lora.astype(jnp.float32))))  # (B,L,D)
    w = w.reshape(B, L, H, hd)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    u = p["u"]                                             # (H, hd)

    s0 = (state["wkv"].astype(jnp.float32) if state
          else jnp.zeros((B, H, hd, hd), jnp.float32))

    def step(S, inp):
        rt, kt, vt, wt = inp                               # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    # two-level scan: outer over chunks (state checkpointed at chunk
    # boundaries), inner over steps, rematerialised on backward — bounds the
    # saved per-step (B,H,hd,hd) residuals to one chunk.
    Q = min(cfg.chunk, L)
    while L % Q:
        Q -= 1
    nC = L // Q

    def to_chunks(a):                                       # (B,L,H,hd)
        return a.transpose(1, 0, 2, 3).reshape(nC, Q, B, H, hd)

    xs = (to_chunks(rf), to_chunks(kf), to_chunks(vf), to_chunks(w))

    @jax.checkpoint
    def chunk_body(S, inp):
        return jax.lax.scan(step, S, inp)

    S_fin, ys = jax.lax.scan(chunk_body, s0, xs)            # ys (nC,Q,B,H,hd)
    y = ys.reshape(L, B, H, hd).transpose(1, 0, 2, 3).reshape(B, L, D)
    y = layer_norm(y.astype(x.dtype), p["ln_x"])
    y = y * g
    out = y @ p["wo"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"shift": last, "wkv": S_fin.astype(state["wkv"].dtype)}
    return out, new_state


def rwkv6_channel_mix(p: Params, x: jnp.ndarray,
                      state: Optional[Params] = None):
    xx, last = _token_shift(x, state["shift"] if state else None)
    mu = p["mu"].astype(x.dtype)
    zk = x + (xx - x) * mu[0]
    zr = x + (xx - x) * mu[1]
    k = jnp.square(jax.nn.relu(zk @ p["wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(zr @ p["wr"].astype(x.dtype)) * \
        (k @ p["wv"].astype(x.dtype))
    new_state = {"shift": last} if state is not None else None
    return out, new_state


def init_rwkv6_state(cfg: RWKVConfig, d: int, batch: int,
                     dtype=jnp.float32) -> Params:
    hd = cfg.head_dim
    H = d // hd
    return {
        "time": {"shift": jnp.zeros((batch, 1, d), dtype),
                 "wkv": jnp.zeros((batch, H, hd, hd), dtype)},
        "channel": {"shift": jnp.zeros((batch, 1, d), dtype)},
    }
