"""Mixture-of-experts MLP (granite-moe) — GShard-style einsum dispatch.

Tokens are grouped (group size g), routed top-k with a capacity limit
C = ceil(g * top_k * capacity_factor / E), dispatched to (E, C, D) buffers by
one-hot einsum, processed by per-expert SwiGLU, and combined with the router
weights.  Experts shard over the 'model' mesh axis; GSPMD materialises the
all-to-all from the (group, expert) resharding.  Overflowing tokens are
dropped (standard GShard semantics) — the residual connection carries them.

The router aux load-balance loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import _dense_init

Params = Dict[str, jnp.ndarray]


def init_moe(key, d: int, cfg: MoEConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.d_expert
    return {
        "router": _dense_init(k1, d, e),
        "w_gate": jax.random.normal(k2, (e, d, f), jnp.float32) / jnp.sqrt(d),
        "w_up": jax.random.normal(k3, (e, d, f), jnp.float32) / jnp.sqrt(d),
        "w_down": jax.random.normal(k4, (e, f, d), jnp.float32) / jnp.sqrt(f),
    }


def moe_mlp(p: Params, x: jnp.ndarray, cfg: MoEConfig,
            group_size: int = 256,
            capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, L, D) -> (out, aux_loss)."""
    B, L, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    n_tok = B * L
    g = min(group_size, n_tok)
    while n_tok % g:
        g -= 1
    G = n_tok // g
    xt = x.reshape(G, g, D)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                             # (G,g,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)     # renorm

    C = max(1, int(g * K * capacity_factor / E))
    if g <= 64:
        # tiny groups (decode): a single expert can receive every token —
        # use lossless capacity so decode matches prefill exactly
        C = max(C, g)
    # position of each (token, k) routing choice within its expert queue
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)                # (G,g,K,E)
    flat = onehot.reshape(G, g * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                       # (G,gK,E)
    pos = (pos_in_e * flat).sum(-1).reshape(G, g, K)                 # (G,g,K)
    keep = pos < C
    # dispatch tensor (G, g, E, C): 1 where token goes to (expert, slot)
    disp = (jax.nn.one_hot(topi, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos, C, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype))                 # (G,g,K,E,C)
    combine = disp * topv[..., None, None].astype(x.dtype)
    disp = disp.sum(2)                                               # (G,g,E,C)
    combine = combine.sum(2)                                         # (G,g,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xt)                      # (G,E,C,D)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                               p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h * u, p["w_down"].astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine, ye)                  # (G,g,D)

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))                                     # (E,)
    fe = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32).mean((0, 1))
    aux = E * jnp.sum(me * fe)
    return out.reshape(B, L, D), aux
