"""Pure-jnp oracles for the Pallas kernels.

The measurement matrix A of the paper's compressive projection is never
materialised at framework scale: entries are generated from a counter-based
integer hash of ``(seed, block, row, col)``.  The SAME hash is implemented
here (pure jnp, the test oracle) and inside the Pallas kernels — kernel
correctness is asserted as exact/allclose agreement with these functions.

Entry distributions:
  * ``rademacher``:  +-1/sqrt(s_block)     (subgaussian, kernel default)
  * gaussian:        N(0, 1/s_block) via Box-Muller from two hash draws
                     (paper-faithful; used by the dense/jnp paths)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_M1 = np.uint32(0x21F0AAAD)
_M2 = np.uint32(0x735A2D97)
_GOLDEN = np.uint32(0x9E3779B9)


def splitmix32(x: jnp.ndarray) -> jnp.ndarray:
    """lowbias32 finalizer; uint32 -> uint32 (wrapping arithmetic)."""
    x = x.astype(jnp.uint32)
    x = x + _GOLDEN
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 15)
    return x


def hash3(seed, block, row, col) -> jnp.ndarray:
    """Chained hash of three coordinates (avoids 64-bit flat indices)."""
    h = splitmix32(jnp.uint32(seed) ^ jnp.asarray(block, jnp.uint32))
    h = splitmix32(h ^ jnp.asarray(row, jnp.uint32))
    h = splitmix32(h ^ jnp.asarray(col, jnp.uint32))
    return h


def _uniform01(h: jnp.ndarray) -> jnp.ndarray:
    # (h + 0.5) / 2^32 in (0, 1)
    return (h.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)


def block_matrix_ref(seed: int, block: jnp.ndarray, s_block: int, c: int,
                     rademacher: bool = True) -> jnp.ndarray:
    """Oracle for one projection block A_b of shape (s_block, c)."""
    rows = jnp.arange(s_block, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(c, dtype=jnp.uint32)[None, :]
    h = hash3(seed, block, rows, cols)
    scale = jnp.float32(1.0 / np.sqrt(s_block))
    if rademacher:
        sign = 1.0 - 2.0 * (h >> 31).astype(jnp.float32)
        return sign * scale
    # Box-Muller from two decorrelated hashes
    h2 = splitmix32(h ^ jnp.uint32(0xDEADBEEF))
    u1 = _uniform01(h)
    u2 = _uniform01(h2)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z * scale


def ota_project_ref(x: jnp.ndarray, seed, s_block: int,
                    rademacher: bool = True) -> jnp.ndarray:
    """Oracle forward projection. x: (n_blocks, c) -> y: (n_blocks, s_block)."""
    n_blocks, c = x.shape

    def one(b, xb):
        A = block_matrix_ref(seed, b, s_block, c, rademacher)
        return A @ xb

    return jax.vmap(one)(jnp.arange(n_blocks, dtype=jnp.uint32), x)


def ota_project_t_ref(y: jnp.ndarray, seed, c: int,
                      rademacher: bool = True) -> jnp.ndarray:
    """Oracle transpose projection. y: (n_blocks, s_block) -> (n_blocks, c)."""
    n_blocks, s_block = y.shape

    def one(b, yb):
        A = block_matrix_ref(seed, b, s_block, c, rademacher)
        return A.T @ yb

    return jax.vmap(one)(jnp.arange(n_blocks, dtype=jnp.uint32), y)


def ef_sparsify_ref(g: jnp.ndarray, delta: jnp.ndarray, tau: jnp.ndarray):
    """Oracle fused error-feedback + threshold sparsification.

    g_ec = g + delta ; keep entries with |g_ec| >= tau ; residual -> new delta.
    Returns (g_sp, new_delta).
    """
    g_ec = g + delta
    keep = jnp.abs(g_ec) >= tau
    g_sp = jnp.where(keep, g_ec, 0.0)
    return g_sp, g_ec - g_sp
