"""Fused single-launch AMP decode kernel (paper §IV, Lemma 1).

The PS-side AMP reconstruction is the per-round hot path of A-DSGD: every
iteration needs one forward and one adjoint pass through the block-diagonal
measurement matrix ``A``, which at framework scale is regenerated from a
counter hash on every use.  Launch-per-op decoding therefore pays
``2 * amp_iters + 1`` A-generations per block (adjoint + forward per
iteration, plus the LS debias).

This kernel is the in-kernel realisation of the chunked-scan structure of
``repro.core.amp.amp_blocked_core``: the grid runs over chunks of
``nb_tile`` blocks, each program generates its chunk's A tile **once** into
VMEM, keeps the AMP carries ``(x, z)`` resident, and runs all ``iters``
soft-threshold/Onsager iterations plus the clamped LS debias inside one
``pallas_call``.  A-generation cost per decode drops to exactly one pass
per block and HBM traffic to O(y + x).

Seed and block-id offset arrive through SMEM as *traced* uint32 scalars so
the shard-folded seeds of the fully-sharded slice driver
(core/distributed.py) use the same kernel.  Validated in interpret mode
against the jnp oracle (tests/test_amp_fused.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pointwise AMP math is shared with the jnp paths (pure-jnp helpers lower
# fine inside a kernel body; core.amp has no module-level kernels import,
# so this does not cycle) — the clamp/epsilon constants live in ONE place
from repro.core.amp import _debias_factor, soft_threshold
from repro.kernels.ota_project import (VMEM_TILE_BYTES, _bdot, _pad_blocks,
                                       _tile_A)


def _amp_kernel(scal_ref, y_ref, x_ref, *, nb_tile, s_block, c, iters,
                threshold_mult, debias, rademacher):
    t = pl.program_id(0)
    seed = scal_ref[0]
    b0 = scal_ref[1] + jnp.uint32(t * nb_tile)
    # ONE A-generation per block, resident in VMEM for the whole decode
    A = _tile_A(seed, b0, jnp.uint32(0), jnp.uint32(0),
                nb_tile, s_block, c, s_block, rademacher)
    y = y_ref[...]                                   # (nb_tile, s_block)
    inv_sqrt_s = jnp.float32(1.0 / (s_block ** 0.5))

    def body(_, carry):
        x, z = carry
        sigma_hat = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True)) \
            * inv_sqrt_s
        r = x + _bdot(A, z, 1, 1)                    # adjoint (MXU)
        x_new = soft_threshold(r, threshold_mult * sigma_hat)
        onsager = z * (jnp.sum(x_new != 0.0, axis=1, keepdims=True)
                       / s_block)
        z_new = y - _bdot(A, x_new, 2, 1) + onsager  # forward (MXU)
        return x_new, z_new

    x0 = jnp.zeros((nb_tile, c), jnp.float32)
    x, z = jax.lax.fori_loop(0, iters, body, (x0, y))
    if debias:
        ax = _bdot(A, x, 2, 1)
        num = jnp.sum(ax * y, axis=1, keepdims=True)
        den = jnp.sum(ax * ax, axis=1, keepdims=True)
        x = x * _debias_factor(num, den)
    x_ref[...] = x


def amp_decode_fused_pallas(yb: jnp.ndarray, seed, c: int, *,
                            iters: int = 20, threshold_mult: float = 1.3,
                            debias: bool = True, rademacher: bool = True,
                            nb_tile: int | None = None, id_offset=0,
                            interpret: bool = True) -> jnp.ndarray:
    """Decode yb: (n_blocks, s_block) -> xb: (n_blocks, c) in one launch.

    ``seed`` and ``id_offset`` (global index of the first block, for
    decoding a sub-range with the encoder's global block ids) may be traced
    uint32 scalars.
    """
    n_blocks, s_block = yb.shape
    # clamp any requested nb_tile to the VMEM budget: callers hand down
    # HBM-sized knobs (MACContext.chunk_blocks), and an A tile past
    # VMEM_TILE_BYTES fails Mosaic compilation on the real-TPU path that
    # interpret-mode CI never exercises
    vmem_cap = max(1, (VMEM_TILE_BYTES // 4) // max(s_block * c, 1))
    nb_tile = vmem_cap if nb_tile is None else min(nb_tile, vmem_cap)
    nb_tile = min(nb_tile, n_blocks)
    y_p = _pad_blocks(yb.astype(jnp.float32), nb_tile)
    scal = jnp.stack([jnp.asarray(seed, jnp.uint32),
                      jnp.asarray(id_offset, jnp.uint32)])
    kern = functools.partial(_amp_kernel, nb_tile=nb_tile, s_block=s_block,
                             c=c, iters=iters, threshold_mult=threshold_mult,
                             debias=debias, rademacher=rademacher)
    xb = pl.pallas_call(
        kern,
        grid=(y_p.shape[0] // nb_tile,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((nb_tile, s_block), lambda t: (t, 0))],
        out_specs=pl.BlockSpec((nb_tile, c), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((y_p.shape[0], c), jnp.float32),
        interpret=interpret,
    )(scal, y_p)
    return xb[:n_blocks]
