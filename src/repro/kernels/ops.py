"""jit'd public wrappers around the Pallas kernels with jnp fallbacks.

``use_kernel=True`` routes through pl.pallas_call (interpret mode on CPU,
compiled Mosaic on TPU); ``use_kernel=False`` uses the pure-jnp oracle path,
which XLA fuses reasonably and which is what the multi-pod dry-run lowers
(Mosaic kernels do not lower on the CPU backend used for dry-runs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ef_sparsify import ef_sparsify_pallas
from repro.kernels.ota_project import ota_project_pallas, ota_project_t_pallas

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("seed", "s_block", "rademacher",
                                             "use_kernel"))
def ota_project(x: jnp.ndarray, *, seed: int, s_block: int,
                rademacher: bool = True, use_kernel: bool = False):
    """Blocked forward projection. x: (n_blocks, c) -> (n_blocks, s_block)."""
    if use_kernel:
        return ota_project_pallas(x, seed, s_block, rademacher,
                                  interpret=_INTERPRET)
    return ref.ota_project_ref(x, seed, s_block, rademacher)


@functools.partial(jax.jit, static_argnames=("seed", "c", "rademacher",
                                             "use_kernel"))
def ota_project_t(y: jnp.ndarray, *, seed: int, c: int,
                  rademacher: bool = True, use_kernel: bool = False):
    """Blocked transpose projection. y: (n_blocks, s_block) -> (n_blocks, c)."""
    if use_kernel:
        return ota_project_t_pallas(y, seed, c, rademacher,
                                    interpret=_INTERPRET)
    return ref.ota_project_t_ref(y, seed, c, rademacher)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def ef_sparsify(g: jnp.ndarray, delta: jnp.ndarray, tau, *,
                use_kernel: bool = False):
    """Fused error-feedback + threshold sparsify. Returns (g_sp, new_delta)."""
    if use_kernel:
        return ef_sparsify_pallas(g, delta, jnp.asarray(tau),
                                  interpret=_INTERPRET)
    return ref.ef_sparsify_ref(g, delta, jnp.asarray(tau))
