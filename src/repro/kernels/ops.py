"""jit'd public wrappers around the Pallas kernels with jnp fallbacks.

``use_kernel=True`` routes through pl.pallas_call (interpret mode off-TPU,
compiled Mosaic on TPU); ``use_kernel=False`` uses the pure-jnp oracle path,
which XLA fuses reasonably and which is what the multi-pod dry-run lowers
(Mosaic kernels do not lower on the CPU backend used for dry-runs).

``seed`` is a regular (traceable) operand on every wrapper: python ints,
concrete arrays and traced uint32 scalars (the shard-folded seeds of the
fully-sharded slice driver) all work.  Backend detection is lazy —
:func:`interpret_default` is evaluated at trace time of each call, never at
import time, so selecting a backend after importing this module works.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.amp_fused import amp_decode_fused_pallas
from repro.kernels.ef_sparsify import ef_sparsify_pallas
from repro.kernels.ota_project import ota_project_pallas, ota_project_t_pallas


def interpret_default() -> bool:
    """Run Pallas in interpret mode?  Evaluated lazily per call (at trace
    time) — an import-time constant would pin the backend before the user
    could select one (e.g. via jax.config / JAX_PLATFORMS)."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("s_block", "rademacher",
                                             "use_kernel", "nb_tile"))
def ota_project(x: jnp.ndarray, *, seed, s_block: int,
                rademacher: bool = True, use_kernel: bool = False,
                nb_tile: int | None = None):
    """Blocked forward projection. x: (n_blocks, c) -> (n_blocks, s_block)."""
    if use_kernel:
        return ota_project_pallas(x, seed, s_block, rademacher,
                                  nb_tile=nb_tile,
                                  interpret=interpret_default())
    return ref.ota_project_ref(x, seed, s_block, rademacher)


@functools.partial(jax.jit, static_argnames=("c", "rademacher",
                                             "use_kernel", "nb_tile"))
def ota_project_t(y: jnp.ndarray, *, seed, c: int,
                  rademacher: bool = True, use_kernel: bool = False,
                  nb_tile: int | None = None):
    """Blocked transpose projection. y: (n_blocks, s_block) -> (n_blocks, c)."""
    if use_kernel:
        return ota_project_t_pallas(y, seed, c, rademacher,
                                    nb_tile=nb_tile,
                                    interpret=interpret_default())
    return ref.ota_project_t_ref(y, seed, c, rademacher)


@functools.partial(jax.jit, static_argnames=("c", "iters", "threshold_mult",
                                             "debias", "rademacher",
                                             "nb_tile"))
def amp_decode_fused(yb: jnp.ndarray, *, seed, c: int, iters: int,
                     threshold_mult: float = 1.3, debias: bool = True,
                     rademacher: bool = True, nb_tile: int | None = None,
                     id_offset=0):
    """Single-launch fused AMP decode (kernels/amp_fused.py).

    The jnp realisation of the same one-generation-per-block structure is
    :func:`repro.core.amp.amp_blocked_core` (use_kernel=False).
    """
    return amp_decode_fused_pallas(yb, seed, c, iters=iters,
                                   threshold_mult=threshold_mult,
                                   debias=debias, rademacher=rademacher,
                                   nb_tile=nb_tile, id_offset=id_offset,
                                   interpret=interpret_default())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def ef_sparsify(g: jnp.ndarray, delta: jnp.ndarray, tau, *,
                use_kernel: bool = False):
    """Fused error-feedback + threshold sparsify. Returns (g_sp, new_delta)."""
    if use_kernel:
        return ef_sparsify_pallas(g, delta, jnp.asarray(tau),
                                  interpret=interpret_default())
    return ref.ef_sparsify_ref(g, delta, jnp.asarray(tau))
