"""Fused error-feedback + threshold sparsification Pallas kernel.

One HBM pass computes, per tile:
    g_ec  = g + delta
    keep  = |g_ec| >= tau
    g_sp  = keep ? g_ec : 0
    delta'= g_ec - g_sp
instead of the 3-pass jnp version (add, compare/select, subtract), which is
memory-bound at d ~ 1e9+.  tau is a scalar (prefetched to SMEM-like operand).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tau_ref, g_ref, d_ref, sp_ref, nd_ref):
    g_ec = g_ref[...] + d_ref[...]
    tau = tau_ref[0]
    keep = jnp.abs(g_ec) >= tau
    sp = jnp.where(keep, g_ec, 0.0)
    sp_ref[...] = sp
    nd_ref[...] = g_ec - sp


def ef_sparsify_pallas(g: jnp.ndarray, delta: jnp.ndarray, tau: jnp.ndarray,
                       tile: int = 1 << 16, interpret: bool | None = None):
    """g, delta: (n,) float32; tau: scalar. Returns (g_sp, new_delta).

    ``n`` is padded up to a multiple of ``tile`` and the outputs sliced
    back — the tile never shrinks, so a prime-length gradient launches
    ceil(n/tile) programs, not n.  The pad lanes are pure zeros (0 + 0
    compared against tau >= 0 stays 0 in both outputs), so padding is
    value-exact for the real lanes.  ``interpret=None`` resolves lazily
    per call to the same backend detection as :mod:`repro.kernels.ops`
    (which imports this module, hence the local check).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    (n,) = g.shape
    tile = min(tile, n)
    pad = (-n) % tile
    n_pad = n + pad
    grid = (n_pad // tile,)
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    g_p = jnp.pad(g.astype(jnp.float32), (0, pad))
    d_p = jnp.pad(delta.astype(jnp.float32), (0, pad))
    out_shape = (jax.ShapeDtypeStruct((n_pad,), jnp.float32),
                 jax.ShapeDtypeStruct((n_pad,), jnp.float32))
    sp, nd = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))),
        out_shape=out_shape,
        interpret=interpret,
    )(tau_arr, g_p, d_p)
    return sp[:n], nd[:n]
