"""Fused error-feedback + threshold sparsification Pallas kernel.

One HBM pass computes, per tile:
    g_ec  = g + delta
    keep  = |g_ec| >= tau
    g_sp  = keep ? g_ec : 0
    delta'= g_ec - g_sp
instead of the 3-pass jnp version (add, compare/select, subtract), which is
memory-bound at d ~ 1e9+.  tau is a scalar (prefetched to SMEM-like operand).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tau_ref, g_ref, d_ref, sp_ref, nd_ref):
    g_ec = g_ref[...] + d_ref[...]
    tau = tau_ref[0]
    keep = jnp.abs(g_ec) >= tau
    sp = jnp.where(keep, g_ec, 0.0)
    sp_ref[...] = sp
    nd_ref[...] = g_ec - sp


def ef_sparsify_pallas(g: jnp.ndarray, delta: jnp.ndarray, tau: jnp.ndarray,
                       tile: int = 1 << 16, interpret: bool = True):
    """g, delta: (n,) float32; tau: scalar. Returns (g_sp, new_delta)."""
    (n,) = g.shape
    tile = min(tile, n)
    while n % tile:
        tile -= 1
    grid = (n // tile,)
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    out_shape = (jax.ShapeDtypeStruct((n,), jnp.float32),
                 jax.ShapeDtypeStruct((n,), jnp.float32))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))),
        out_shape=out_shape,
        interpret=interpret,
    )(tau_arr, g.astype(jnp.float32), delta.astype(jnp.float32))
