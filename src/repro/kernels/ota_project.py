"""Pallas TPU kernels for the on-the-fly blocked compressive projection.

The paper (§IV) projects each device's sparsified gradient with a shared
pseudo-random matrix ``A``.  At framework scale A cannot live in HBM
(s x d = O(1e20) entries for a 100B model), so these kernels generate each
VMEM tile of A from a counter-based hash (see kernels/ref.py) *inside* the
matmul kernel: HBM traffic is O(d + s) and A never exists.

TPU adaptation notes (docs/DESIGN.md §4): each grid program batches
``nb_tile`` blocks and contracts them with one batched ``dot_general``
(MXU) instead of a per-block matvec; the VPU generates the next A tile's
entries from integer hashes while the MXU consumes the previous one
(software pipelining by the Mosaic compiler); Rademacher entries (one hash
+ sign) instead of Box-Muller Gaussians.  The seed arrives through SMEM as
a *traced* uint32 scalar, so the shard-folded seeds of the fully-sharded
slice driver (core/distributed.py) lower through the same kernels.

Kernels are validated in interpret mode against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _GOLDEN, _M1, _M2

#: VMEM budget for one program's A tile (bytes); actual VMEM is ~16 MiB/core,
#: leave room for x/y blocks, the double-buffered next tile and AMP carries.
VMEM_TILE_BYTES = 4 << 20


# ---------------------------------------------------------------------------
# in-kernel hash (identical math to ref.splitmix32 / ref.hash3)
# ---------------------------------------------------------------------------


def _splitmix32(x):
    x = x + _GOLDEN
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 15)
    return x


def _tile_A(seed, block0, row0, col0, nb_tile: int, r_tile: int, c_tile: int,
            s_block: int, rademacher: bool):
    """Generate the (nb_tile, r_tile, c_tile) stacked-A tile whose first
    block is ``block0``, starting at entry (row0, col0) of each block.

    ``seed``/``block0`` may be traced uint32 scalars (SMEM-prefetched)."""
    shape = (nb_tile, r_tile, c_tile)
    blocks = block0 + jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, shape, 2)
    h = _splitmix32(jnp.uint32(seed) ^ blocks)
    h = _splitmix32(h ^ rows)
    h = _splitmix32(h ^ cols)
    scale = jnp.float32(1.0 / (s_block ** 0.5))
    if rademacher:
        sign = 1.0 - 2.0 * (h >> 31).astype(jnp.float32)
        return sign * scale
    h2 = _splitmix32(h ^ jnp.uint32(0xDEADBEEF))
    u1 = (h.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)
    u2 = (h2.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z * scale


def _bdot(a, b, contract_a: int, contract_b: int):
    """Batched (leading-dim) contraction on the MXU in f32."""
    return jax.lax.dot_general(
        a, b, (((contract_a,), (contract_b,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _divisor_tile(n: int, budget_elems: int) -> int:
    """Largest divisor of n with at most budget_elems elements."""
    t = max(1, min(n, budget_elems))
    while n % t:
        t -= 1
    return t


def _pick_tiles(n_blocks: int, inner: int, other: int,
                nb_tile: int | None, inner_tile: int | None):
    """(nb_tile, inner_tile) fitting one A tile in VMEM_TILE_BYTES.

    ``inner`` is the tiled A dimension (rows for forward, cols for adjoint),
    ``other`` the un-tiled one.  nb_tile batches blocks per program."""
    budget = VMEM_TILE_BYTES // 4
    if inner_tile is None:
        inner_tile = _divisor_tile(inner, max(1, budget // max(other, 1)))
    assert inner % inner_tile == 0
    # a requested nb_tile is clamped to the VMEM budget too — callers hand
    # down HBM-sized knobs, and an oversized A tile fails Mosaic on TPU
    cap = max(1, budget // max(inner_tile * other, 1))
    nb_tile = cap if nb_tile is None else max(1, min(nb_tile, cap))
    return min(nb_tile, n_blocks), inner_tile


def _pad_blocks(x: jnp.ndarray, nb_tile: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % nb_tile
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _seed_arr(seed) -> jnp.ndarray:
    """[seed] as a uint32 SMEM operand; accepts python ints and traced
    scalars (e.g. the shard-folded seeds of the slice driver)."""
    return jnp.asarray(seed, jnp.uint32).reshape(1)


# ---------------------------------------------------------------------------
# forward projection: y[b] = A_b @ x[b],  nb_tile blocks per program
# ---------------------------------------------------------------------------


def _fwd_kernel(seed_ref, x_ref, y_ref, *, nb_tile, s_tile, s_block, c,
                rademacher):
    g = pl.program_id(0)                 # block-chunk index
    i = pl.program_id(1)                 # row-tile index inside s_block
    b0 = jnp.uint32(g * nb_tile)
    A = _tile_A(seed_ref[0], b0, jnp.uint32(i * s_tile), jnp.uint32(0),
                nb_tile, s_tile, c, s_block, rademacher)
    x = x_ref[...]                       # (nb_tile, c)
    y_ref[...] = _bdot(A, x, 2, 1)       # (nb_tile, s_tile)


def ota_project_pallas(x: jnp.ndarray, seed, s_block: int,
                       rademacher: bool = True, nb_tile: int | None = None,
                       s_tile: int | None = None,
                       interpret: bool = True) -> jnp.ndarray:
    """x: (n_blocks, c) float32 -> y: (n_blocks, s_block) float32."""
    n_blocks, c = x.shape
    nb_tile, s_tile = _pick_tiles(n_blocks, s_block, c, nb_tile, s_tile)
    x_p = _pad_blocks(x.astype(jnp.float32), nb_tile)
    grid = (x_p.shape[0] // nb_tile, s_block // s_tile)
    kern = functools.partial(_fwd_kernel, nb_tile=nb_tile, s_tile=s_tile,
                             s_block=s_block, c=c, rademacher=rademacher)
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((nb_tile, c), lambda g, i: (g, 0))],
        out_specs=pl.BlockSpec((nb_tile, s_tile), lambda g, i: (g, i)),
        out_shape=jax.ShapeDtypeStruct((x_p.shape[0], s_block), jnp.float32),
        interpret=interpret,
    )(_seed_arr(seed), x_p)
    return y[:n_blocks]


# ---------------------------------------------------------------------------
# transpose projection: r[b] = A_b^T @ y[b]   (AMP's adjoint step)
# ---------------------------------------------------------------------------


def _t_kernel(seed_ref, y_ref, o_ref, *, nb_tile, c_tile, s_block,
              rademacher):
    g = pl.program_id(0)
    j = pl.program_id(1)                 # col-tile index inside c
    b0 = jnp.uint32(g * nb_tile)
    A = _tile_A(seed_ref[0], b0, jnp.uint32(0), jnp.uint32(j * c_tile),
                nb_tile, s_block, c_tile, s_block, rademacher)
    y = y_ref[...]                       # (nb_tile, s_block)
    o_ref[...] = _bdot(A, y, 1, 1)       # (nb_tile, c_tile)


def ota_project_t_pallas(y: jnp.ndarray, seed, c: int,
                         rademacher: bool = True, nb_tile: int | None = None,
                         c_tile: int | None = None,
                         interpret: bool = True) -> jnp.ndarray:
    """y: (n_blocks, s_block) float32 -> (n_blocks, c) float32."""
    n_blocks, s_block = y.shape
    nb_tile, c_tile = _pick_tiles(n_blocks, c, s_block, nb_tile, c_tile)
    y_p = _pad_blocks(y.astype(jnp.float32), nb_tile)
    grid = (y_p.shape[0] // nb_tile, c // c_tile)
    kern = functools.partial(_t_kernel, nb_tile=nb_tile, c_tile=c_tile,
                             s_block=s_block, rademacher=rademacher)
    o = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((nb_tile, s_block), lambda g, j: (g, 0))],
        out_specs=pl.BlockSpec((nb_tile, c_tile), lambda g, j: (g, j)),
        out_shape=jax.ShapeDtypeStruct((y_p.shape[0], c), jnp.float32),
        interpret=interpret,
    )(_seed_arr(seed), y_p)
    return o[:n_blocks]
