"""Pallas TPU kernels for the on-the-fly blocked compressive projection.

The paper (§IV) projects each device's sparsified gradient with a shared
pseudo-random matrix ``A``.  At framework scale A cannot live in HBM
(s x d = O(1e20) entries for a 100B model), so these kernels generate each
VMEM tile of A from a counter-based hash (see kernels/ref.py) *inside* the
matmul kernel: HBM traffic is O(d + s) and A never exists.

TPU adaptation notes (DESIGN.md §4): MXU-aligned tiles (multiples of 128 on
the contracting/lane dims), VPU generates the next A tile's entries from
integer hashes while the MXU consumes the previous one (software pipelining
by the Mosaic compiler), Rademacher entries (one hash + sign) instead of
Box-Muller Gaussians.

Kernels are validated in interpret mode against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import _GOLDEN, _M1, _M2

# ---------------------------------------------------------------------------
# in-kernel hash (identical math to ref.splitmix32 / ref.hash3)
# ---------------------------------------------------------------------------


def _splitmix32(x):
    x = x + _GOLDEN
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 15)
    return x


def _tile_A(seed: int, block, row0, col0, s_tile: int, c_tile: int,
            s_block: int, rademacher: bool):
    """Generate the (s_tile, c_tile) tile of A_block starting at (row0, col0)."""
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (s_tile, c_tile), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (s_tile, c_tile), 1)
    h = _splitmix32(jnp.uint32(seed) ^ block.astype(jnp.uint32))
    h = _splitmix32(h ^ rows)
    h = _splitmix32(h ^ cols)
    scale = jnp.float32(1.0 / (s_block ** 0.5))
    if rademacher:
        sign = 1.0 - 2.0 * (h >> 31).astype(jnp.float32)
        return sign * scale
    h2 = _splitmix32(h ^ jnp.uint32(0xDEADBEEF))
    u1 = (h.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)
    u2 = (h2.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z * scale


# ---------------------------------------------------------------------------
# forward projection: y[b] = A_b @ x[b]
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, y_ref, *, seed, s_tile, s_block, c, rademacher):
    b = pl.program_id(0)
    i = pl.program_id(1)
    A = _tile_A(seed, b, (i * s_tile).astype(jnp.uint32), jnp.uint32(0),
                s_tile, c, s_block, rademacher)
    x = x_ref[0, :]                     # (c,)
    y_ref[0, :] = A @ x                  # (s_tile,)


def ota_project_pallas(x: jnp.ndarray, seed: int, s_block: int,
                       rademacher: bool = True, s_tile: int | None = None,
                       interpret: bool = True) -> jnp.ndarray:
    """x: (n_blocks, c) float32 -> y: (n_blocks, s_block) float32."""
    n_blocks, c = x.shape
    if s_tile is None:
        # keep the A tile under ~4 MiB of VMEM, MXU-aligned when possible
        s_tile = max(1, min(s_block, (4 * 1024 * 1024 // 4) // max(c, 1)))
        while s_block % s_tile:
            s_tile -= 1
    assert s_block % s_tile == 0
    grid = (n_blocks, s_block // s_tile)
    kern = functools.partial(_fwd_kernel, seed=seed, s_tile=s_tile,
                             s_block=s_block, c=c, rademacher=rademacher)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, c), lambda b, i: (b, 0))],
        out_specs=pl.BlockSpec((1, s_tile), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, s_block), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# transpose projection: r[b] = A_b^T @ y[b]   (AMP's adjoint step)
# ---------------------------------------------------------------------------


def _t_kernel(y_ref, o_ref, *, seed, c_tile, s_block, rademacher):
    b = pl.program_id(0)
    j = pl.program_id(1)
    A = _tile_A(seed, b, jnp.uint32(0), (j * c_tile).astype(jnp.uint32),
                s_block, c_tile, s_block, rademacher)   # (s_block, c_tile)
    y = y_ref[0, :]                      # (s_block,)
    o_ref[0, :] = y @ A                  # (c_tile,)


def ota_project_t_pallas(y: jnp.ndarray, seed: int, c: int,
                         rademacher: bool = True, c_tile: int | None = None,
                         interpret: bool = True) -> jnp.ndarray:
    """y: (n_blocks, s_block) float32 -> (n_blocks, c) float32."""
    n_blocks, s_block = y.shape
    if c_tile is None:
        c_tile = max(1, min(c, (4 * 1024 * 1024 // 4) // max(s_block, 1)))
        while c % c_tile:
            c_tile -= 1
    assert c % c_tile == 0
    grid = (n_blocks, c // c_tile)
    kern = functools.partial(_t_kernel, seed=seed, c_tile=c_tile,
                             s_block=s_block, rademacher=rademacher)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, s_block), lambda b, j: (b, 0))],
        out_specs=pl.BlockSpec((1, c_tile), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, c), jnp.float32),
        interpret=interpret,
    )(y.astype(jnp.float32))
