"""Deterministic fault traces: who misbehaves, when, and how.

A fault trace is a pure function of ``(fault_key, round_key, device_id)``,
so it evaluates identically inside a compiled ``lax.scan``, in a looped
reference run, and under ``vmap`` — the same contract the fading processes
follow (:mod:`repro.core.fading`).  Two key streams with different
lifetimes:

* **Persistent Byzantine membership** comes from the run-level
  ``fault_key`` (:func:`fault_base_key`, derived from ``OTAConfig.seed``):
  a device is Byzantine for the whole run, and because membership is
  thresholding one fixed uniform draw per device, the Byzantine sets are
  *nested and monotone* in ``byzantine_frac`` — a swept fraction axis
  grows the attacker set instead of reshuffling it (common random numbers
  for paired comparisons).
* **Transient faults** (NaN/Inf frame poisoning, stale-update replay,
  mid-round dropout, digital packet erasure) redraw each round from the
  fault-salted round key (``fold_in(round_key, SALT_FAULT)``, salt 6 in
  the engine's key layout — 0 MAC AWGN, 1 encode, 2 channel draw, 3
  availability, 4 cohort sampling, 5 straggler latency).

The draw shape is ``(m,)`` booleans per fault class (:class:`FaultDraw`);
rates are *traced* scalars so the sweep engine vmaps whole fault grids on
one program (``ROBUST_VMAP_AXES``), while the fault *kind* and the attack
*shape* are static strings that select program structure.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

#: round-key salt owned by the fault layer (see the engine's salt table)
SALT_FAULT = 6

#: decorrelates the run-level Byzantine stream from the fading stream
FAULT_SEED_SALT = 0x0FA1175


def fault_base_key(seed: int) -> jnp.ndarray:
    """Run-level key anchoring the persistent Byzantine membership.

    Derived from ``OTAConfig.seed`` like ``fading.fading_base_key``: the
    attacker *set* is a property of the run configuration, not of the
    per-round key stream, so a ``seed`` sweep axis (which shifts the round
    keys) holds the Byzantine set fixed across replicas.
    """
    return jax.random.PRNGKey(seed ^ FAULT_SEED_SALT)


class FaultDraw(NamedTuple):
    """One round's fault realisation over ``m`` devices (all ``(m,)`` bool).

    ``byz`` is the persistent Byzantine set; exactly one of
    ``poison`` / ``stale`` / ``dropout`` carries the transient draw (the
    static ``fault_kind`` selects which — the others are all-False
    constants that gate to nothing); ``erased`` is the independent digital
    packet-erasure draw.  ``poison_value`` is the static NaN/Inf payload.
    """
    byz: jnp.ndarray
    poison: jnp.ndarray
    stale: jnp.ndarray
    dropout: jnp.ndarray
    erased: jnp.ndarray
    poison_value: float = float("nan")


def byzantine_set(fault_key: jnp.ndarray, m: int, byzantine_frac) -> jnp.ndarray:
    """(m,) bool persistent Byzantine membership, nested in the fraction."""
    u = jax.random.uniform(fault_key, (m,))
    return u < jnp.asarray(byzantine_frac, jnp.float32)


def fault_draw(fault_key: jnp.ndarray, key: jnp.ndarray, m: int, *,
               byzantine_frac, fault_rate, erasure_prob,
               fault_kind: str = "nan") -> FaultDraw:
    """Evaluate the fault trace for one round.

    ``key`` is the fault-salted round key (``fold_in(round_key,
    SALT_FAULT)``); callers own the salt, matching the channel-draw
    convention.  Rates are traced; ``fault_kind`` is static.
    """
    if fault_kind not in ("nan", "inf", "stale", "dropout"):
        raise ValueError(f"unknown fault_kind {fault_kind!r}; "
                         "known: nan | inf | stale | dropout")
    byz = byzantine_set(fault_key, m, byzantine_frac)
    hit = (jax.random.uniform(key, (m,))
           < jnp.asarray(fault_rate, jnp.float32))
    erased = (jax.random.uniform(jax.random.fold_in(key, 1), (m,))
              < jnp.asarray(erasure_prob, jnp.float32))
    none = jnp.zeros((m,), bool)
    return FaultDraw(
        byz=byz,
        poison=hit if fault_kind in ("nan", "inf") else none,
        stale=hit if fault_kind == "stale" else none,
        dropout=hit if fault_kind == "dropout" else none,
        erased=erased,
        poison_value=float("inf") if fault_kind == "inf" else float("nan"),
    )


def apply_gradient_faults(grads: jnp.ndarray, fault: FaultDraw, *,
                          byz_attack: str = "sign_flip",
                          byz_scale=10.0) -> jnp.ndarray:
    """Device-side (pre-encode) gradient transforms.

    * Byzantine ``sign_flip``: g -> -byz_scale * g (coordinated directional
      attack); ``scale``: g -> byz_scale * g (magnitude attack).
    * Stale devices contribute g = 0 this round: the encode then replays
      whatever residual their error accumulator banked — a stale-update
      replay with error-feedback semantics intact.

    Poisoning is *not* a gradient transform — sparsifying encodes filter
    non-finite coordinates structurally (a NaN fails every top-k magnitude
    compare and drops out of the frame), so a gradient-level NaN never
    reaches the MAC.  The physical fault is a transmitter emitting garbage
    on the air interface: :func:`apply_frame_faults` poisons the encoded
    frame instead.  Dropout and erasure act on the transmit set, not the
    gradient — the drivers fold them into the active mask.
    """
    if byz_attack not in ("sign_flip", "scale"):
        raise ValueError(f"unknown byz_attack {byz_attack!r}; "
                         "known: sign_flip | scale")
    g = grads
    sgn = -1.0 if byz_attack == "sign_flip" else 1.0
    scale = sgn * jnp.asarray(byz_scale, g.dtype)
    g = jnp.where(fault.byz[:, None], scale * g, g)
    g = jnp.where(fault.stale[:, None], 0.0, g)
    return g


def apply_frame_faults(frames: jnp.ndarray, fault: FaultDraw) -> jnp.ndarray:
    """Air-interface poisoning: faulty transmitters emit NaN/Inf frames.

    Applied *after* encode (and after any transmit-side power clip — a
    hardware limiter cannot repair a broken DAC), so the garbage reaches
    the MAC sum exactly as a malfunctioning radio's would.  The unaware
    device's error-feedback state evolves as if its real frame had been
    sent — the same semantics as a packet erasure.
    """
    return jnp.where(fault.poison[:, None],
                     jnp.asarray(fault.poison_value, frames.dtype), frames)


def take_rows(fault: FaultDraw, cohort: jnp.ndarray) -> FaultDraw:
    """The cohort's rows of a full-population fault draw (the population
    engine's gather, mirroring ``Scheme.cohort_channel_draw``)."""
    return FaultDraw(*(jnp.take(v, cohort, axis=0)
                       for v in fault[:5]), fault.poison_value)
