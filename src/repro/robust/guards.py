"""Round guardrails inside the jit scan: clamp, skip, back off — no host.

A single NaN decode or a diverging loss normally poisons every subsequent
round of a compiled run silently.  :func:`guarded_step` wraps the
PS-side optimizer application with three traced safety rails, all of
which stay inside one ``jit(lax.scan)`` (every decision is a ``where``
select on the carry — no host callback, no trace break, no retry loop):

* **update-norm clamp** (``update_clip > 0``): the decoded update's L2
  norm is capped before it reaches the optimizer.
* **finite check + skip-round fallback** (``skip_nonfinite``): if the
  decoded update is non-finite, the round is skipped — params, optimizer
  state, and every accumulator in ``extras`` are carried unchanged.
* **divergence detector + LR backoff** (``divergence_factor > 0``): if
  the post-step eval loss exceeds ``divergence_factor *`` the last
  accepted loss (or goes non-finite), the step is reverted and the
  traced ``lr_scale`` is multiplied by ``lr_backoff``; a cooldown
  counter then suppresses further backoffs for ``cooldown`` rounds so
  one bad stretch cannot collapse the LR geometrically.

``lr_scale`` is applied by *blending the applied step*
(``p0 + lr_scale * (p1 - p0)``) rather than scaling the gradient —
Adam's update is invariant to gradient scaling, so a gradient-side
scale would be a no-op exactly when the backoff is needed most.  The
blend is structurally gated: a guard-free engine never builds it, so
default runs stay bitwise-identical (``p0 + 1.0*(p1 - p0) != p1``
bitwise in IEEE arithmetic).

Engine wiring: ``Experiment.guard`` / ``PopulationExperiment.guard``
take a :class:`GuardConfig`; the scan carry then grows a
:class:`GuardState` tail and the per-round metrics gain
``guard_lr_scale`` / ``guard_skipped`` / ``guard_backoff`` columns.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GuardConfig:
    """Static guardrail configuration (trace structure; 0 disables a rail)."""
    update_clip: float = 0.0       # L2 cap on the decoded update (0 = off)
    skip_nonfinite: bool = True    # skip rounds with NaN/Inf updates
    divergence_factor: float = 0.0  # revert if loss > factor * last (0 = off)
    lr_backoff: float = 0.5        # lr_scale multiplier on divergence
    cooldown: int = 5              # rounds between successive backoffs


class GuardState(NamedTuple):
    """Traced guardrail state riding the scan carry."""
    lr_scale: jnp.ndarray          # current LR backoff multiplier
    cooldown: jnp.ndarray          # rounds until the next backoff may fire
    prev_loss: jnp.ndarray         # loss at the last accepted step
    skips: jnp.ndarray             # cumulative skipped rounds
    backoffs: jnp.ndarray          # cumulative LR backoffs


def init_guard_state() -> GuardState:
    return GuardState(lr_scale=jnp.float32(1.0),
                      cooldown=jnp.float32(0.0),
                      prev_loss=jnp.float32(jnp.inf),
                      skips=jnp.float32(0.0),
                      backoffs=jnp.float32(0.0))


def _select(ok, new: Any, old: Any) -> Any:
    """Traced pytree select: ``new`` where ok, else ``old``."""
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


def guarded_step(guard: GuardConfig, gstate: GuardState, opt, params,
                 opt_state, ghat: jnp.ndarray, unravel, extras: Any,
                 old_extras: Any, loss_fn):
    """One guarded PS update.  Returns
    ``(params, opt_state, extras, gstate, loss, guard_metrics)``.

    ``extras``/``old_extras`` are the round's remaining carry (error
    accumulators, momenta, banks) in post-/pre-round form: a skipped or
    reverted round restores ``old_extras`` wholesale, so error feedback
    cannot absorb an update that was never applied.  ``loss_fn(params)``
    is the divergence detector's eval (the engines pass their existing
    test-set loss, so the detector costs one extra eval only when the
    divergence rail is on).
    """
    if guard.update_clip > 0:
        nrm = jnp.sqrt(jnp.sum(ghat.astype(jnp.float32) ** 2))
        ghat = ghat * jnp.minimum(1.0, guard.update_clip
                                  / jnp.maximum(nrm, 1e-30))
    finite = jnp.all(jnp.isfinite(ghat))
    # a non-finite update would corrupt Adam's moments even on a skipped
    # round — apply the optimizer to a zeroed stand-in and discard it
    ghat_safe = jnp.where(finite, ghat, 0.0)
    p1, o1 = opt.apply(params, unravel(ghat_safe), opt_state)
    # LR backoff by step blending (Adam is scale-invariant in the gradient)
    p1 = jax.tree.map(lambda p0, p: p0 + gstate.lr_scale * (p - p0),
                      params, p1)

    skip = (~finite) if guard.skip_nonfinite else jnp.asarray(False)
    if guard.divergence_factor > 0:
        loss1 = loss_fn(p1)
        diverged = ((~jnp.isfinite(loss1))
                    | (loss1 > guard.divergence_factor * gstate.prev_loss))
        diverged = diverged & (gstate.cooldown <= 0.0) & ~skip
    else:
        loss1 = None
        diverged = jnp.asarray(False)
    revert = skip | diverged

    ok = ~revert
    params = _select(ok, p1, params)
    opt_state = _select(ok, o1, opt_state)
    extras = _select(ok, extras, old_extras)

    if loss1 is None:
        loss = loss_fn(params)
    else:
        # reverted rounds report the last accepted loss (= loss(params))
        loss = jnp.where(ok, loss1, gstate.prev_loss)
    new_gstate = GuardState(
        lr_scale=jnp.where(diverged, gstate.lr_scale * guard.lr_backoff,
                           gstate.lr_scale),
        cooldown=jnp.where(diverged, jnp.float32(guard.cooldown),
                           jnp.maximum(gstate.cooldown - 1.0, 0.0)),
        prev_loss=jnp.where(ok, loss, gstate.prev_loss),
        skips=gstate.skips + skip.astype(jnp.float32),
        backoffs=gstate.backoffs + diverged.astype(jnp.float32),
    )
    metrics = {"guard_lr_scale": new_gstate.lr_scale,
               "guard_skipped": skip.astype(jnp.float32),
               "guard_backoff": diverged.astype(jnp.float32)}
    return params, opt_state, extras, new_gstate, loss, metrics
