"""Fault injection, robust aggregation, and round guardrails.

The graceful-degradation layer: deterministic fault traces
(:mod:`repro.robust.faults`), influence-bounded combines
(:mod:`repro.robust.aggregators`), and in-scan safety rails
(:mod:`repro.robust.guards`).  Wiring lives in the drivers
(``round_masked`` / ``population_round``) and the compiled engines;
see docs/DESIGN.md §10.
"""
from repro.robust.aggregators import (
    clip_frame_power, median, norm_capped_sum, robust_combine, trimmed_mean,
)
from repro.robust.faults import (
    SALT_FAULT, FaultDraw, apply_frame_faults, apply_gradient_faults,
    byzantine_set, fault_base_key, fault_draw, take_rows,
)
from repro.robust.guards import (
    GuardConfig, GuardState, guarded_step, init_guard_state,
)

__all__ = [
    "SALT_FAULT",
    "FaultDraw",
    "GuardConfig",
    "GuardState",
    "apply_frame_faults",
    "apply_gradient_faults",
    "byzantine_set",
    "clip_frame_power",
    "fault_base_key",
    "fault_draw",
    "guarded_step",
    "init_guard_state",
    "median",
    "norm_capped_sum",
    "robust_combine",
    "take_rows",
    "trimmed_mean",
]
