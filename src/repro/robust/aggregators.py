"""Robust aggregation: trimmed-mean / median / norm-cap combines.

The digital drivers aggregate device frames with a plain ``sum`` over the
leading axis — one Byzantine device moves the aggregate arbitrarily.  The
combines here bound that influence, coordinate-wise (trim / median) or
per-frame (norm cap), while fitting the drivers' contract: each returns a
*sum-equivalent* ``(s,)`` vector (the robust mean times the effective
device count), so the scheme's ``decode`` — which divides by the traced
``ctx.m`` — needs no change.

Everything here is traced-friendly: the trim fraction, the norm cap, and
the effective device count are data (vmappable sweep axes); only the
aggregator *name* is static.  Dead rows (masked-out, erased, dropped
devices) are routed to ``+inf`` before the sort, and the traced rank
window — computed from the *live* row count — excludes them.  Note the
exactness boundary: a sorted-and-trimmed sum *re-associates* the
reduction, so ``trimmed_mean`` at ``trim_frac=0`` equals the arithmetic
mean mathematically but not bitwise — which is why the drivers keep the
literal ``jnp.sum`` on the static ``aggregator="mean"`` path instead of
routing it through here.
"""
from __future__ import annotations

import jax.numpy as jnp


def _n_alive(alive: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(jnp.sum(alive.astype(jnp.float32)), 1.0)


def _rank_window_mean(frames: jnp.ndarray, alive: jnp.ndarray, lo, hi):
    """Mean over sort-ranks ``[lo, hi]`` per coordinate, dead rows excluded.

    frames: (m, s); alive: (m,) bool; lo/hi: traced inclusive rank bounds
    within the live rows (live rows sort before the +inf dead rows, and
    non-finite live values — a poisoned device's frame — sort last among
    the live, where an adequate trim removes them).
    """
    big = jnp.asarray(jnp.inf, frames.dtype)
    x = jnp.where(alive[:, None], frames, big)
    xs = jnp.sort(x, axis=0)
    i = jnp.arange(frames.shape[0], dtype=jnp.float32)[:, None]
    keep = (i >= lo) & (i <= hi)
    count = jnp.maximum(hi - lo + 1.0, 1.0)
    return jnp.sum(jnp.where(keep, xs, 0.0), axis=0) / count


def trimmed_mean(frames: jnp.ndarray, alive: jnp.ndarray,
                 trim_frac) -> jnp.ndarray:
    """(s,) coordinate-wise trimmed mean over the live rows.

    Discards the ``floor(trim_frac * n_alive)`` smallest and largest
    values per coordinate; ``trim_frac`` is traced and the live count is
    computed from ``alive``.  Robust to up to that many outliers per side.
    """
    n = _n_alive(alive)
    lo = jnp.floor(jnp.asarray(trim_frac, jnp.float32) * n)
    # degenerate cohorts: never trim away every row
    lo = jnp.minimum(lo, jnp.maximum(jnp.ceil(n / 2.0) - 1.0, 0.0))
    return _rank_window_mean(frames, alive, lo, n - 1.0 - lo)


def median(frames: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """(s,) coordinate-wise median over the live rows (maximal trimming:
    the mean of the one or two middle ranks)."""
    n = _n_alive(alive)
    lo = jnp.floor((n - 1.0) / 2.0)
    return _rank_window_mean(frames, alive, lo, n - 1.0 - lo)


def norm_capped_sum(frames: jnp.ndarray, alive: jnp.ndarray,
                    cap) -> jnp.ndarray:
    """(s,) sum of the live frames, each L2-clipped to ``cap`` *times the
    median live-row norm*.

    The reference scale is the coordinate-wise :func:`median` of the live
    rows' L2 norms — itself Byzantine-robust below 50% attackers — so the
    cap self-tunes to the honest gradient scale instead of needing an
    absolute magnitude guess; ``cap = 1.0`` clips every row to the median
    norm.  Honest frames at or below the cap pass through with scale
    exactly 1.0.  Non-finite rows (a poisoned frame has no meaningful
    norm) contribute exactly zero — the norm cap doubles as the NaN/Inf
    filter, which matters for the *sparse* digital schemes where
    coordinate-wise trimming is destructive (a top-k frame's signal lives
    at the extreme ranks, precisely what a trim discards; the per-frame
    cap leaves sparse supports intact).
    """
    cap = jnp.asarray(cap, frames.dtype)
    nrm = jnp.sqrt(jnp.sum(frames * frames, axis=-1, keepdims=True))
    med = median(nrm, alive)
    # a majority-poisoned round has a non-finite median norm: degrade to
    # an all-zero (skipped) aggregate rather than poisoning honest rows
    cap_abs = cap * jnp.where(jnp.isfinite(med), med, 0.0)
    finite = jnp.isfinite(nrm)
    scale = jnp.where(nrm <= cap_abs, 1.0,
                      cap_abs / jnp.maximum(nrm, 1e-30))
    scale = jnp.where(finite, scale, 0.0)
    f_safe = jnp.where(finite, frames, 0.0)
    return jnp.sum(f_safe * scale * alive[:, None].astype(frames.dtype),
                   axis=0)


def robust_combine(frames: jnp.ndarray, alive: jnp.ndarray, m_eff, *,
                   aggregator: str, trim_frac=0.1,
                   norm_cap=1.0) -> jnp.ndarray:
    """Sum-equivalent robust combine (the drivers' digital-MAC hook).

    Returns ``m_eff *`` the robust mean, so a decode dividing by the
    traced ``ctx.m == m_eff`` recovers the robust mean exactly where the
    plain path recovers the arithmetic mean.  ``aggregator`` is static;
    everything else is traced.
    """
    if aggregator == "trimmed_mean":
        return trimmed_mean(frames, alive, trim_frac) * m_eff
    if aggregator == "median":
        return median(frames, alive) * m_eff
    if aggregator == "norm_cap":
        return norm_capped_sum(frames, alive, norm_cap)
    raise ValueError(f"unknown aggregator {aggregator!r}; "
                     "known: mean | trimmed_mean | median | norm_cap")


def clip_frame_power(frames: jnp.ndarray, p_max) -> jnp.ndarray:
    """Transmit-side hardware power cap for analog OTA frames.

    Rows whose energy exceeds ``p_max`` are rescaled onto the cap; rows at
    or below it pass through untouched (scale exactly 1.0).  An honest
    A-DSGD frame is normalised to ``P_t`` by ``channel.make_frame``, so a
    cap of ``power_cap * P_t`` with ``power_cap > 1`` leaves honest
    devices alone while flattening a Byzantine device's power boost —
    the analog analogue of the digital norm cap (an analog attacker
    cannot move the OTA sum without spending receive power, and the cap
    bounds the power it can spend).
    """
    p_max = jnp.asarray(p_max, frames.dtype)
    energy = jnp.sum(frames * frames, axis=-1, keepdims=True)
    scale = jnp.where(energy > p_max,
                      jnp.sqrt(p_max / jnp.maximum(energy, 1e-30)), 1.0)
    return frames * scale
