"""Geometry channel model + subband scheduling (DESIGN.md §12).

Acceptance bar: geometry/scheduling OFF is *bitwise* the pre-axis code
(pinned by the committed goldens, which predate the axis); geometry ON is
pinned by its own golden; the scheduler layer is tested against its policy
contracts (cycle coverage, top-S selection, proportional-fair state), and
the compiled/population engines against the dense round with the axis at
its identity point.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core import geometry, scheduling
from repro.core.schemes import get_scheme, round_simulated
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import run_compiled, run_sweep
from repro.population import (
    PopulationConfig, PopulationData, run_population,
)
from repro.experiments.sweep import run_population_sweep

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from tests.golden.parity_cases import PARITY_CASES  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulated_parity.npz")
D, M = 256, 6
STEPS = 6


def _cfg(**kw):
    base = dict(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=10, projection="dense", amp_iters=10,
                mean_removal_steps=2, fading="rayleigh",
                fading_threshold=0.9)
    base.update(kw)
    return OTAConfig(**base)


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=800, n_test=300, dim=48, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=64, iid=True, seed=0)
    return (xd, yd), (xte, yte)


# ---------------------------------------------------------------------------
# geometry math
# ---------------------------------------------------------------------------


def test_unit_positions_area_uniform_in_disk():
    """r = sqrt(U) puts devices area-uniformly in the unit disk: all radii
    <= 1 and E[r^2] = 1/2 (uniform area measure), not E[r] = 1/2."""
    r, theta = geometry.unit_positions(jax.random.PRNGKey(0), 4000)
    r, theta = np.asarray(r), np.asarray(theta)
    assert r.max() <= 1.0 and r.min() >= 0.0
    assert np.mean(r ** 2) == pytest.approx(0.5, abs=0.02)
    assert theta.min() >= 0.0 and theta.max() <= 2 * np.pi


def test_distances_bounded_by_radius_and_mast():
    spec = geometry.GeometrySpec(bs_height=10.0)
    d = np.asarray(geometry.device_distances(
        jax.random.PRNGKey(1), 1000, jnp.float32(500.0), spec))
    assert d.min() >= spec.bs_height            # never closer than the mast
    assert d.max() <= np.hypot(500.0, spec.bs_height) + 1e-3


def test_gains_decrease_with_radius_and_exponent():
    """Larger cells and steeper path loss both weaken the median link."""
    key = jax.random.PRNGKey(2)
    spec = geometry.GeometrySpec()
    med = lambda radius, gamma: float(np.median(np.asarray(
        geometry.large_scale_gains(key, 500, jnp.float32(radius),
                                   jnp.float32(gamma), spec))))
    assert med(100.0, 3.0) > med(400.0, 3.0) > med(1600.0, 3.0)
    assert med(1600.0, 2.0) > med(1600.0, 3.0) > med(1600.0, 4.0)


def test_gain_is_antenna_product_at_reference_distance():
    """At d == ref_dist the normalised power law is exactly the antenna
    gains — the (d/d0)^-gamma factor is 1."""
    spec = geometry.GeometrySpec(bs_gain_db=5.0, user_gain_db=1.0,
                                 ref_dist=100.0, bs_height=100.0)
    # cell_radius -> 0 pins every distance at bs_height == ref_dist
    g = np.asarray(geometry.large_scale_gains(
        jax.random.PRNGKey(3), 8, jnp.float32(1e-6), jnp.float32(3.0), spec))
    np.testing.assert_allclose(g, 10.0 ** 0.6, rtol=1e-5)


def test_link_budget_diagnostics_monotone():
    spec = geometry.GeometrySpec(carrier_freq=915e6)
    near = float(geometry.link_budget_db(jnp.float32(100.0), 3.0, spec))
    far = float(geometry.link_budget_db(jnp.float32(1000.0), 3.0, spec))
    assert far < near                            # more loss further out
    f1 = float(geometry.fspl_db(jnp.float32(1000.0), 915e6))
    f2 = float(geometry.fspl_db(jnp.float32(1000.0), 2 * 915e6))
    assert f2 == pytest.approx(f1 + 20 * np.log10(2), abs=1e-3)


def test_geometry_key_is_run_level_and_seeded():
    k0 = geometry.geometry_base_key(0)
    k1 = geometry.geometry_base_key(1)
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))
    g0 = geometry.large_scale_gains(k0, M, jnp.float32(500.0),
                                    jnp.float32(3.0), geometry.GeometrySpec())
    g0b = geometry.large_scale_gains(k0, M, jnp.float32(500.0),
                                     jnp.float32(3.0), geometry.GeometrySpec())
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g0b))


def test_spec_from_cfg_validates_kind():
    with pytest.raises(ValueError, match="geometry"):
        geometry.spec_from_cfg(_cfg(geometry="torus"))
    spec = geometry.spec_from_cfg(_cfg(geometry="disk", bs_gain_db=7.0))
    assert spec.bs_gain_db == 7.0


# ---------------------------------------------------------------------------
# scheme composition: bitwise off, multiplicative on
# ---------------------------------------------------------------------------


def test_geometry_off_channel_draw_is_small_scale_draw():
    """geometry='none' compiles no gain op: the base channel_draw returns
    the small-scale draw object untouched (bitwise, all schemes)."""
    for scheme in ("a_dsgd", "a_dsgd_csi_err", "a_dsgd_blind"):
        sch = get_scheme(_cfg(scheme=scheme, csi_err_var=0.25,
                              ps_antennas=16), D, M)
        key = jax.random.PRNGKey(5)
        a = sch.channel_draw(key, 0, M)
        b = sch.small_scale_draw(key, 0, M)
        np.testing.assert_array_equal(np.asarray(a.p_factor),
                                      np.asarray(b.p_factor))
        np.testing.assert_array_equal(np.asarray(a.active),
                                      np.asarray(b.active))


def test_geometry_on_multiplies_p_factor():
    sch = get_scheme(_cfg(geometry="disk", cell_radius=500.0), D, M)
    key = jax.random.PRNGKey(5)
    small = sch.small_scale_draw(key, 0, M)
    full = sch.channel_draw(key, 0, M)
    gains = sch.geometry_gains(M)
    np.testing.assert_array_equal(
        np.asarray(full.p_factor),
        np.asarray(small.p_factor * gains))
    # the transmit set is the small-scale truncation decision, unchanged
    np.testing.assert_array_equal(np.asarray(full.active),
                                  np.asarray(small.active))


def test_geometry_golden_pinned():
    """The committed a_dsgd_geometry golden reproduces bitwise."""
    cfg = PARITY_CASES["a_dsgd_geometry"]
    sch = get_scheme(cfg, D, M)
    gold = np.load(GOLDEN)
    grads = jnp.asarray(gold["grads"])
    deltas = jnp.zeros((M, D), jnp.float32)
    ghat, nd, _ = round_simulated(sch, grads, deltas, 0,
                                  jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(ghat),
                                  gold["a_dsgd_geometry__ghat"])
    np.testing.assert_array_equal(np.asarray(nd),
                                  gold["a_dsgd_geometry__deltas"])


def test_cohort_draw_carries_geometry(data):
    """cohort_channel_draw takes cohort rows of the full-M geometry-scaled
    realisation — device identity, not cohort position, keys the gain."""
    cfg = _cfg(geometry="disk", cell_radius=500.0)
    sch = get_scheme(cfg, D, M)
    key = jax.random.PRNGKey(5)
    cohort = jnp.asarray([4, 1, 3])
    full = sch.channel_draw(key, 0, M)
    sub = sch.cohort_channel_draw(key, 0, cohort, M,
                                  mask=jnp.ones((3,), bool))
    np.testing.assert_array_equal(np.asarray(sub.p_factor),
                                  np.asarray(full.p_factor)[[4, 1, 3]])


# ---------------------------------------------------------------------------
# scheduler contracts
# ---------------------------------------------------------------------------


def _sched(name, **kw):
    return scheduling.get_scheduler(_cfg(scheduler=name, **kw))


def test_registry_resolution():
    assert scheduling.get_scheduler(_cfg(scheduler="none")) is None
    assert set(scheduling.registered_schedulers()) == {
        "round_robin", "gain_ranked", "prop_fair"}
    with pytest.raises(KeyError, match="unknown scheduler"):
        scheduling.get_scheduler(_cfg(scheduler="magic"))
    with pytest.raises(ValueError, match="n_subbands"):
        scheduling.get_scheduler(_cfg(scheduler="round_robin", n_subbands=0))


def test_round_robin_cycles_all_devices():
    """S subbands/round: every device is served exactly once per M/S-round
    cycle, in index order."""
    s = _sched("round_robin", n_subbands=2)
    gains = jnp.ones((M,))
    served = []
    for t in range(M // 2):
        sel, _ = scheduling.schedule(s, jax.random.PRNGKey(t), t, gains,
                                     jnp.float32(2.0))
        assert int(np.sum(np.asarray(sel))) == 2
        served.extend(np.flatnonzero(np.asarray(sel)).tolist())
    assert sorted(served) == list(range(M))


def test_gain_ranked_picks_top_s():
    s = _sched("gain_ranked", n_subbands=3)
    gains = jnp.asarray([0.1, 5.0, 0.3, 4.0, 0.2, 3.0])
    sel, _ = scheduling.schedule(s, jax.random.PRNGKey(0), 0, gains,
                                 jnp.float32(3.0))
    np.testing.assert_array_equal(np.asarray(sel),
                                  [False, True, False, True, False, True])


def test_prop_fair_state_decays_priority_of_served():
    """A device served every round sees its average rise and its priority
    fall below an equally-strong never-served device."""
    s = _sched("prop_fair", n_subbands=1, pf_horizon=4.0)
    gains = jnp.asarray([2.0, 2.0])
    state = s.init_state(2)
    sel, state = scheduling.schedule(s, jax.random.PRNGKey(0), 0, gains,
                                     jnp.float32(1.0), state=state)
    first = int(np.flatnonzero(np.asarray(sel))[0])
    sel2, state2 = scheduling.schedule(s, jax.random.PRNGKey(1), 1, gains,
                                       jnp.float32(1.0), state=state)
    second = int(np.flatnonzero(np.asarray(sel2))[0])
    assert second != first                       # fairness alternates
    assert float(state[first]) > float(state[1 - first])
    assert float(state2[second]) > 0.0


def test_schedule_masked_devices_never_serve():
    s = _sched("gain_ranked", n_subbands=4)
    gains = jnp.asarray([9.0, 8.0, 7.0, 1.0, 0.5, 0.1])
    mask = jnp.asarray([False, False, True, True, True, True])
    sel, _ = scheduling.schedule(s, jax.random.PRNGKey(0), 0, gains,
                                 jnp.float32(4.0), mask=mask)
    sel = np.asarray(sel)
    assert not sel[0] and not sel[1]             # masked: never scheduled
    np.testing.assert_array_equal(sel[2:], [True, True, True, True])


def test_n_subbands_is_traced_vmappable():
    """One trace serves a whole subband-budget grid (the k_active rank
    pattern): vmapping over n_subbands matches per-value calls."""
    s = _sched("gain_ranked")
    gains = jax.random.uniform(jax.random.PRNGKey(0), (M,))
    budgets = jnp.asarray([1.0, 3.0, 5.0])

    def one(nsb):
        sel, _ = scheduling.schedule(s, jax.random.PRNGKey(1), 0, gains, nsb)
        return sel

    batched = jax.vmap(one)(budgets)
    for i, nsb in enumerate(budgets):
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(one(nsb)))
        assert int(np.sum(np.asarray(batched[i]))) == int(nsb)


def test_schedule_deterministic_tie_break():
    """Equal priorities break by device index (stable argsort) — bitwise
    reproducible across calls."""
    s = _sched("gain_ranked", n_subbands=2)
    gains = jnp.ones((M,))
    sel, _ = scheduling.schedule(s, jax.random.PRNGKey(0), 0, gains,
                                 jnp.float32(2.0))
    np.testing.assert_array_equal(np.asarray(sel),
                                  [True, True, False, False, False, False])


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def test_compiled_full_budget_schedule_is_identity(data):
    """scheduler ON with n_subbands == M schedules everyone: bitwise the
    unscheduled run (the scheduler branch routes through round_masked,
    which is pinned bitwise-equal to round_simulated at the all-ones
    mask)."""
    (xd, yd), (xte, yte) = data
    base = run_compiled(xd, yd, xte, yte, _cfg(total_steps=STEPS),
                        steps=STEPS, eval_every=2)
    full = run_compiled(xd, yd, xte, yte,
                        _cfg(total_steps=STEPS, scheduler="gain_ranked",
                             n_subbands=M),
                        steps=STEPS, eval_every=2)
    np.testing.assert_array_equal(np.asarray(base.accs),
                                  np.asarray(full.accs))


def test_compiled_scheduler_restricts_transmit_set(data):
    (xd, yd), (xte, yte) = data
    run = run_compiled(xd, yd, xte, yte,
                       _cfg(total_steps=STEPS, scheduler="round_robin",
                            n_subbands=2),
                       steps=STEPS, eval_every=2)
    # active_frac counts the post-schedule transmit set
    assert max(m["active_frac"] for m in run.metrics) <= 2 / M + 1e-6


def test_run_federated_rejects_scheduler(data):
    from repro.train.paper_repro import run_federated
    (xd, yd), (xte, yte) = data
    with pytest.raises(ValueError, match="scheduler"):
        run_federated(np.asarray(xd), np.asarray(yd), xte, yte,
                      _cfg(scheduler="round_robin"), steps=2)


def test_population_full_cohort_matches_dense_with_scheduler(data):
    """K == M population with prop_fair (banked state) reproduces the
    dense engine (carried state) bitwise — the banked-vs-carried PF
    average is the same vector when every slot is hot."""
    (xd, yd), (xte, yte) = data
    cfg = _cfg(total_steps=STEPS, geometry="disk", cell_radius=500.0,
               scheduler="prop_fair", n_subbands=2)
    dense = run_compiled(xd, yd, xte, yte, cfg, steps=STEPS, eval_every=2)
    popr = run_population(PopulationData.from_dense(xd, yd), xte, yte, cfg,
                          PopulationConfig(m_total=M, k_cohort=M),
                          steps=STEPS, eval_every=2)
    np.testing.assert_array_equal(np.asarray(dense.accs),
                                  np.asarray(popr.accs))


def test_population_sampled_cohort_scheduler_runs(data):
    (xd, yd), (xte, yte) = data
    cfg = _cfg(total_steps=STEPS, geometry="disk", cell_radius=800.0,
               scheduler="prop_fair", n_subbands=2)
    run = run_population(PopulationData.from_dense(xd, yd), xte, yte, cfg,
                         PopulationConfig(m_total=M, k_cohort=4,
                                          capacity=4, bank_size=2),
                         steps=STEPS, eval_every=2)
    assert np.all(np.isfinite(np.asarray(run.accs)))


# ---------------------------------------------------------------------------
# sweep axes
# ---------------------------------------------------------------------------


def test_sweep_geometry_axes_vmapped_match_single_runs(data):
    """cell_radius / n_subbands ride the vmapped trace; each grid point is
    bitwise its standalone compiled run."""
    (xd, yd), (xte, yte) = data
    base = _cfg(total_steps=STEPS, geometry="disk",
                scheduler="gain_ranked")
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"cell_radius": [200.0, 900.0], "n_subbands": [2, 4]},
                    steps=STEPS, eval_every=2)
    assert len(res.records) == 4
    for rec in res.records:
        cfg = _cfg(total_steps=STEPS, geometry="disk",
                   scheduler="gain_ranked",
                   cell_radius=rec["cell_radius"],
                   n_subbands=int(rec["n_subbands"]))
        solo = run_compiled(xd, yd, xte, yte, cfg, steps=STEPS,
                            eval_every=2)
        np.testing.assert_array_equal(np.asarray(rec["accs"]),
                                      np.asarray(solo.accs))


def test_population_sweep_scheduler_static_axis(data):
    (xd, yd), (xte, yte) = data
    res = run_population_sweep(
        PopulationData.from_dense(xd, yd), (xte, yte),
        _cfg(total_steps=STEPS, geometry="disk"),
        PopulationConfig(m_total=M, k_cohort=M),
        {"scheduler": ["round_robin", "gain_ranked"],
         "cell_radius": [300.0, 1200.0]},
        steps=STEPS, eval_every=2)
    assert len(res.records) == 4
    assert {r["scheduler"] for r in res.records} == {"round_robin",
                                                     "gain_ranked"}
    assert all(np.all(np.isfinite(r["accs"])) for r in res.records)
