"""Beyond-paper extensions the paper invites (§I-B): FedAvg local steps and
DGC-style momentum correction, both through the same wireless MAC."""
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.data.synthetic import federated_split, make_classification
from repro.train.paper_repro import run_federated


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(n_train=5000, n_test=1200,
                                                 noise=6.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=8, b=300, iid=True, seed=0)
    return xd, yd, xte, yte


def _run(data, **kw):
    xd, yd, xte, yte = data
    ota = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                    total_steps=20, projection="dense", amp_iters=12,
                    mean_removal_steps=5)
    return run_federated(xd, yd, xte, yte, ota, steps=20, lr=1e-3,
                         eval_every=20, **kw)


@pytest.mark.slow
def test_local_sgd_improves_per_round(data):
    """J local steps per round transmit a richer innovation: with the same
    number of communication rounds, accuracy should not be worse."""
    acc_1 = _run(data).accs[-1]
    acc_j = _run(data, local_steps=5, local_lr=0.05).accs[-1]
    assert acc_j > acc_1 - 0.02, (acc_1, acc_j)


@pytest.mark.slow
def test_momentum_correction_trains(data):
    acc_m = _run(data, momentum_correction=0.9).accs[-1]
    assert acc_m > 0.4, acc_m


@pytest.mark.slow
def test_rayleigh_fading_with_truncated_inversion(data):
    """Beyond-paper channel model (follow-up [34]): A-DSGD still trains on a
    Rayleigh-fading MAC with truncated channel inversion; deep-faded devices
    keep their updates in the error accumulator."""
    xd, yd, xte, yte = data
    ota = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                    total_steps=25, projection="dense", amp_iters=12,
                    mean_removal_steps=5, fading="rayleigh",
                    fading_threshold=0.3)
    run = run_federated(xd, yd, xte, yte, ota, steps=25, lr=1e-3,
                        eval_every=25)
    assert run.accs[-1] > 0.4, run.accs
    # participation fraction matches the Rayleigh CDF:
    # P(h >= t) = exp(-t^2) for |CN(0,1)| => ~0.914 at t = 0.3
    fracs = [m["active_frac"] for m in run.metrics]
    assert 0.7 < np.mean(fracs) <= 1.0


def test_fading_gains_statistics():
    import jax
    import jax.numpy as jnp
    from repro.core.channel import rayleigh_gains, truncated_inversion_power
    h = rayleigh_gains(jax.random.PRNGKey(0), 20000)
    # E[h^2] = 1 for |CN(0,1)|
    assert abs(float(jnp.mean(h * h)) - 1.0) < 0.05
    pfac, active = truncated_inversion_power(h, 0.5)
    assert abs(float(jnp.mean(active)) - np.exp(-0.25)) < 0.02
    assert float(pfac[~active].sum()) == 0.0
