"""Compiled sweep engine: parity with the reference loop + sweep semantics.

The acceptance bar for the engine is *bitwise* agreement with the legacy
path: one engine step must equal ``round_simulated`` + a manual ADAM
update, and a vmapped grid must reproduce the per-point looped runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core.schemes import get_scheme, round_simulated
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import (
    CompiledExperiment, Experiment, eval_indices, round_keys, run_compiled,
    run_sweep,
)
from repro.optim.optim import Optimizer
from repro.train.paper_repro import device_grads, run_federated

STEPS, EVERY, M, B = 6, 2, 4, 64


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=800, n_test=300, dim=48, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=B, iid=True, seed=0)
    return (xd, yd), (xte, yte)


def _adsgd(**kw):
    base = dict(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=STEPS, projection="dense", amp_iters=6,
                mean_removal_steps=2)
    base.update(kw)
    return OTAConfig(**base)


# ---------------------------------------------------------------------------
# bitwise parity with the reference implementation
# ---------------------------------------------------------------------------


def test_engine_step_bitwise_equals_round_simulated_plus_adam(data):
    """One scan step == round_simulated + a manual ADAM update (fixed seed)."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    exp = Experiment(cfg=cfg, steps=1, lr=1e-3, eval_every=1)
    ce = CompiledExperiment(xd, yd, xte, yte, exp)
    keys = round_keys(1)
    eng = jax.jit(ce.run)({}, keys)

    scheme = get_scheme(cfg, ce.d, M)
    opt = Optimizer(name="adam", lr=1e-3)

    @jax.jit
    def reference(params, t, key):
        deltas = jnp.zeros((M, ce.d), jnp.float32)
        momenta = jnp.zeros((M, ce.d), jnp.float32)
        grads, _ = device_grads(params, ce.unravel, jnp.asarray(xd),
                                jnp.asarray(yd), momenta)
        ghat, deltas, _ = round_simulated(scheme, grads, deltas, t, key)
        params, _ = opt.apply(params, ce.unravel(ghat), opt.init(params))
        return params

    params_ref = reference(ce.params0, 0, jax.random.PRNGKey(1000))
    for leaf_e, leaf_r in zip(jax.tree.leaves(eng["params"]),
                              jax.tree.leaves(params_ref)):
        np.testing.assert_array_equal(np.asarray(leaf_e), np.asarray(leaf_r))


@pytest.mark.parametrize("scheme", ["ideal", "a_dsgd", "d_dsgd"])
def test_run_compiled_matches_run_federated(data, scheme):
    """Full compiled scan == the looped reference, entry for entry."""
    (xd, yd), (xte, yte) = data
    cfg = (_adsgd() if scheme == "a_dsgd"
           else OTAConfig(scheme=scheme, s_frac=0.5, p_avg=500.0,
                          total_steps=STEPS))
    ref = run_federated(xd, yd, xte, yte, cfg, steps=STEPS, lr=1e-3,
                        eval_every=EVERY)
    eng = run_compiled(xd, yd, xte, yte, cfg, steps=STEPS, lr=1e-3,
                       eval_every=EVERY)
    assert eng.accs == ref.accs
    assert eng.losses == ref.losses
    for me, mr in zip(eng.metrics, ref.metrics):
        assert me == mr


def test_sweep_vmapped_p_grid_matches_looped_runs(data):
    """The vmapped P-bar axis reproduces per-point looped runs bitwise —
    for the analog scheme (traced power schedule) and the digital scheme
    (traced q schedule under the shared static q_max)."""
    (xd, yd), (xte, yte) = data
    for base in (_adsgd(), OTAConfig(scheme="d_dsgd", s_frac=0.5,
                                     total_steps=STEPS)):
        res = run_sweep((xd, yd), (xte, yte), base,
                        {"p_avg": [50.0, 500.0]}, steps=STEPS,
                        eval_every=EVERY)
        for p in (50.0, 500.0):
            loop = run_federated(xd, yd, xte, yte,
                                 dataclasses.replace(base, p_avg=p),
                                 steps=STEPS, lr=1e-3, eval_every=EVERY)
            assert res.record(p_avg=p)["accs"] == loop.accs


def test_sweep_fading_axes_vmapped_match_looped(data):
    """The channel-model scalars ride the vmapped path (one XLA program per
    static combo, never re-jitted per point) and reproduce per-point looped
    runs bitwise — including the csi_err_var = 0 point, which must equal a
    looped run of the *perfect-CSI* fading scheme (zero estimation error
    degrades bitwise, per the golden)."""
    (xd, yd), (xte, yte) = data
    base = _adsgd(scheme="a_dsgd_csi_err", fading_threshold=0.2)
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"csi_err_var": [0.0, 0.4],
                     "fading_threshold": [0.2, 0.6]},
                    steps=STEPS, eval_every=EVERY)
    assert len(res.records) == 4
    for ev in (0.0, 0.4):
        for thr in (0.2, 0.6):
            loop = run_federated(
                xd, yd, xte, yte,
                dataclasses.replace(base, csi_err_var=ev,
                                    fading_threshold=thr),
                steps=STEPS, lr=1e-3, eval_every=EVERY)
            assert res.record(csi_err_var=ev,
                              fading_threshold=thr)["accs"] == loop.accs
    perfect = run_federated(
        xd, yd, xte, yte,
        dataclasses.replace(base, scheme="a_dsgd_fading",
                            fading_threshold=0.6),
        steps=STEPS, lr=1e-3, eval_every=EVERY)
    assert res.record(csi_err_var=0.0,
                      fading_threshold=0.6)["accs"] == perfect.accs


def test_sweep_fading_rho_axis_gauss_markov(data):
    """fading_rho vmaps over the windowed-MA weights of the gauss_markov
    process; each point still equals its looped run bitwise."""
    (xd, yd), (xte, yte) = data
    base = _adsgd(scheme="a_dsgd_fading", fading_process="gauss_markov",
                  fading_window=16, fading_threshold=0.3)
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"fading_rho": [0.2, 0.95]}, steps=STEPS,
                    eval_every=EVERY)
    r_lo, r_hi = res.record(fading_rho=0.2), res.record(fading_rho=0.95)
    assert r_lo["accs"] != r_hi["accs"]
    loop = run_federated(xd, yd, xte, yte,
                         dataclasses.replace(base, fading_rho=0.95),
                         steps=STEPS, lr=1e-3, eval_every=EVERY)
    assert r_hi["accs"] == loop.accs


def test_sweep_power_schedule_axis(data):
    """power_schedule vmaps through the same (T,) schedule array."""
    (xd, yd), (xte, yte) = data
    base = OTAConfig(scheme="d_dsgd", s_frac=0.5, p_avg=200.0,
                     total_steps=STEPS)
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"power_schedule": ["constant", "hl_steps"]},
                    steps=STEPS, eval_every=EVERY)
    loop = run_federated(xd, yd, xte, yte,
                         dataclasses.replace(base, power_schedule="hl_steps"),
                         steps=STEPS, lr=1e-3, eval_every=EVERY)
    assert res.record(power_schedule="hl_steps")["accs"] == loop.accs


# ---------------------------------------------------------------------------
# padded device-count sweeps
# ---------------------------------------------------------------------------


def test_m_active_full_mask_matches_unmasked(data):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    res = run_sweep((xd, yd), (xte, yte), cfg, {"m_active": [3, M]},
                    steps=STEPS, eval_every=EVERY)
    full = run_federated(xd, yd, xte, yte, cfg, steps=STEPS, lr=1e-3,
                         eval_every=EVERY)
    assert res.record(m_active=M)["accs"] == full.accs
    assert res.record(m_active=3)["accs"] != full.accs


def test_m_active_ideal_mask_equals_true_subset(data):
    """Ideal scheme has no encode RNG, so masking M_pad -> 2 devices must
    reproduce a genuine 2-device run bitwise (decode divides by the traced
    effective device count)."""
    (xd, yd), (xte, yte) = data
    cfg = OTAConfig(scheme="ideal", total_steps=STEPS)
    res = run_sweep((xd, yd), (xte, yte), cfg, {"m_active": [2]},
                    steps=STEPS, eval_every=EVERY)
    two = run_federated(xd[:2], yd[:2], xte, yte, cfg, steps=STEPS, lr=1e-3,
                        eval_every=EVERY)
    assert res.record(m_active=2)["accs"] == two.accs


# ---------------------------------------------------------------------------
# kernel threading, seeds, schema
# ---------------------------------------------------------------------------


def test_use_kernel_runs_inside_scan(data):
    """MACContext.use_kernel routes the blocked projection + fused AMP
    through Pallas (interpret mode off-TPU) inside the scanned loop."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(projection="blocked", block_size=64, amp_iters=4)
    jnp_run = run_compiled(xd, yd, xte, yte, cfg, steps=3, eval_every=1,
                           use_kernel=False)
    krn_run = run_compiled(xd, yd, xte, yte, cfg, steps=3, eval_every=1,
                           use_kernel=True)
    np.testing.assert_allclose(jnp_run.all_accs, krn_run.all_accs, atol=1e-3)


def test_seed_axis_changes_channel_noise(data):
    (xd, yd), (xte, yte) = data
    res = run_sweep((xd, yd), (xte, yte), _adsgd(), {"seed": [0, 1]},
                    steps=STEPS, eval_every=EVERY)
    r0, r1 = res.record(seed=0), res.record(seed=1)
    assert r0["accs"] != r1["accs"]           # different AWGN draws
    # seed 0 is the reference key stream
    loop = run_federated(xd, yd, xte, yte, _adsgd(), steps=STEPS, lr=1e-3,
                         eval_every=EVERY)
    assert r0["accs"] == loop.accs


def test_sweep_result_schema(data):
    (xd, yd), (xte, yte) = data
    res = run_sweep((xd, yd), (xte, yte), _adsgd(),
                    {"scheme": ["a_dsgd", "d_dsgd"], "p_avg": [500.0]},
                    steps=STEPS, eval_every=EVERY)
    assert len(res.records) == 2
    n_evals = len(eval_indices(STEPS, EVERY))
    for rec in res.records:
        assert rec["scheme"] in ("a_dsgd", "d_dsgd")
        assert len(rec["accs"]) == n_evals
        assert rec["final_acc"] == rec["accs"][-1]
        assert rec["us_per_call"] > 0
        assert len(rec["metrics"]) == n_evals
    with pytest.raises(KeyError):
        res.record(scheme="qsgd")


def test_sweep_unknown_axis_raises(data):
    (xd, yd), (xte, yte) = data
    with pytest.raises(KeyError, match="unknown sweep axis"):
        run_sweep((xd, yd), (xte, yte), _adsgd(), {"warp_factor": [9]},
                  steps=2)
