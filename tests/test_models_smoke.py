"""Required per-arch smoke tests: a REDUCED same-family variant runs one
forward + one train step on CPU; output shapes + no NaN (brief §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, loss_fn, param_count
from repro.optim.optim import Optimizer


def _batch(cfg, key, B=2, L=24):
    batch = {"tokens": jax.random.randint(key, (B, L), 0, cfg.vocab)}
    if cfg.mrope_sections is not None:
        P = cfg.n_vision_tokens
        batch["extra"] = 0.02 * jax.random.normal(key, (B, P, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(P + L)[None, :, None], (B, P + L, 3)).astype(jnp.int32)
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.encoder.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_arch_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert param_count(params) > 0
    batch = _batch(cfg, key)

    # forward: loss finite
    (loss, metrics) = jax.jit(
        lambda p, b: loss_fn(p, cfg, b, remat=False))(params, batch)
    loss, metrics = jax.device_get((loss, metrics))
    assert jnp.isfinite(loss), arch
    assert metrics["loss"] > 0

    # one SGD train step: params move, loss decreases on the same batch
    opt = Optimizer(name="adam", lr=5e-3)
    state = opt.init(params)
    g = jax.jit(jax.grad(lambda p: loss_fn(p, cfg, batch, remat=True)[0]))(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all()), arch
    params2, _ = opt.apply(params, g, state)
    loss2 = jax.jit(lambda p, b: loss_fn(p, cfg, b, remat=False)[0])(params2,
                                                                     batch)
    assert float(loss2) < float(loss), (arch, float(loss), float(loss2))


def test_logit_shapes_full_seq():
    cfg = get_config("smollm_360m").reduced()
    from repro.models import transformer
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 16), jnp.int32)
    logits, _, _ = transformer.forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab)


def test_chunked_loss_matches_unchunked():
    cfg = get_config("smollm_360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab)}
    l0 = float(loss_fn(params, cfg, batch, remat=False, loss_chunk=0,
                       compute_dtype=jnp.float32)[0])
    l1 = float(loss_fn(params, cfg, batch, remat=False, loss_chunk=8,
                       compute_dtype=jnp.float32)[0])
    assert abs(l0 - l1) < 1e-4
