"""Property tests for the robust aggregators (hypothesis-driven).

The deterministic counterparts live in tests/test_robust.py; these sweep
randomised shapes/masks/fractions.  The exact-equality property uses
integer-valued floats: summing integers (within the float32 exact range)
is associative, so ``trimmed_mean`` at ``trim_frac=0`` must equal the
arithmetic mean *exactly*, not just to tolerance — pinning that the rank
window covers every live row.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra "
    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.robust import aggregators  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _frames_and_alive(seed, m, s, dead_frac):
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.normal(size=(m, s)), jnp.float32)
    alive = jnp.asarray(rng.random(m) >= dead_frac, bool)
    # degenerate all-dead masks are the drivers' empty-cohort case; keep
    # at least one live row so the reference reductions are defined
    if not bool(alive.any()):
        alive = alive.at[0].set(True)
    return frames, alive


@given(st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 9),
       st.floats(0.0, 0.49), st.floats(0.0, 0.6))
def test_trimmed_mean_within_live_bounds(seed, m, s, trim, dead):
    """Per coordinate, the trimmed mean lies in [min, max] of live rows."""
    frames, alive = _frames_and_alive(seed, m, s, dead)
    out = np.asarray(aggregators.trimmed_mean(frames, alive, trim))
    live = np.asarray(frames)[np.asarray(alive)]
    assert (out >= live.min(axis=0) - 1e-5).all()
    assert (out <= live.max(axis=0) + 1e-5).all()


@given(st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 9),
       st.floats(0.0, 0.49))
def test_trimmed_mean_permutation_invariant(seed, m, s, trim):
    """Reordering devices cannot change a rank-windowed combine."""
    frames, alive = _frames_and_alive(seed, m, s, 0.3)
    perm = jnp.asarray(np.random.default_rng(seed ^ 0xA5).permutation(m))
    a = np.asarray(aggregators.trimmed_mean(frames, alive, trim))
    b = np.asarray(aggregators.trimmed_mean(frames[perm], alive[perm], trim))
    np.testing.assert_array_equal(a, b)


@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(1, 9))
def test_trimmed_mean_trim_zero_is_exact_mean_on_integers(seed, m, s):
    """trim_frac=0 covers every live row: exact equality on integer data.

    Integer sums are exact in float32 regardless of association, so the
    sorted-and-summed trimmed mean and the plain mean divide the *same*
    float32 sum by the same count — bitwise equality, pinning that the
    zero-trim rank window is [0, n-1].
    """
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.integers(-100, 100, size=(m, s)), jnp.float32)
    alive = jnp.ones(m, bool)
    out = np.asarray(aggregators.trimmed_mean(frames, alive, 0.0))
    ref = np.asarray(frames).sum(axis=0) / np.float32(m)
    np.testing.assert_array_equal(out, ref)


@given(st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 9),
       st.floats(0.0, 0.5))
def test_median_permutation_invariant_and_bounded(seed, m, s, dead):
    frames, alive = _frames_and_alive(seed, m, s, dead)
    perm = jnp.asarray(np.random.default_rng(seed ^ 0x5A).permutation(m))
    a = np.asarray(aggregators.median(frames, alive))
    b = np.asarray(aggregators.median(frames[perm], alive[perm]))
    np.testing.assert_array_equal(a, b)
    live = np.asarray(frames)[np.asarray(alive)]
    ref = np.median(live, axis=0)
    np.testing.assert_allclose(a, ref, rtol=1e-5, atol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(3, 12), st.integers(1, 9),
       st.floats(0.5, 3.0))
def test_norm_cap_sum_bounded_by_capped_row_norms(seed, m, s, cap):
    """The aggregate norm is at most the sum of capped live-row norms.

    Each live row enters the sum scaled so its norm is at most
    ``min(||row||, cap * median live norm)`` — the triangle inequality
    then bounds the aggregate, however adversarial any single row is.
    """
    frames, alive = _frames_and_alive(seed, m, s, 0.2)
    out = np.asarray(aggregators.norm_capped_sum(frames, alive, cap))
    live = np.asarray(frames)[np.asarray(alive)]
    nrm = np.linalg.norm(live, axis=1)
    cap_abs = cap * np.median(nrm)
    assert np.linalg.norm(out) <= np.minimum(nrm, cap_abs).sum() * (
        1 + 1e-5) + 1e-6


@given(st.integers(0, 2**31 - 1), st.integers(1, 10), st.integers(1, 16),
       st.floats(1.0, 1e4))
def test_clip_frame_power_never_exceeds_cap(seed, m, s, p_max):
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.normal(scale=50.0, size=(m, s)), jnp.float32)
    out = np.asarray(aggregators.clip_frame_power(frames, p_max))
    energy = np.sum(out * out, axis=-1)
    assert (energy <= p_max * (1 + 1e-4)).all()
    # rows already under the cap pass through bitwise
    under = np.sum(np.asarray(frames) ** 2, axis=-1) <= p_max
    np.testing.assert_array_equal(out[under], np.asarray(frames)[under])
