"""Fault injection, robust aggregation, guardrails, checkpoint/resume.

Three acceptance bars (docs/DESIGN.md §10):

* **bitwise neutrality** — with every fault rate at zero and every defence
  off, the robust code path reproduces the default path bit for bit (the
  existing goldens must not move);
* **semantic fidelity** — each fault class is equivalent to its physical
  description (stale == zero gradient, dropout == leaving the transmit
  set with banking, poison == garbage on the air interface), and each
  defence measurably counters its attack;
* **bitwise resume** — an interrupted-and-resumed checkpointed run equals
  the uninterrupted run exactly (scan segmentation is pure-function
  composition).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core.schemes import MACContext, get_scheme
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import run_compiled, run_sweep
from repro.experiments.engine import round_keys, round_masked
from repro.experiments.sweep import ROBUST_VMAP_AXES
from repro.robust import aggregators, faults, guards

STEPS, M, B = 6, 8, 64


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=800, n_test=300, dim=48, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=B, iid=True, seed=0)
    return (xd, yd), (xte, yte)


def _adsgd(**kw):
    base = dict(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=STEPS, projection="dense", amp_iters=6,
                mean_removal_steps=2)
    base.update(kw)
    return OTAConfig(**base)


def _ddsgd(**kw):
    base = dict(scheme="d_dsgd", k_frac=0.25, p_avg=500.0,
                total_steps=STEPS)
    base.update(kw)
    return OTAConfig(**base)


# ---------------------------------------------------------------------------
# fault traces: determinism, nesting, cohort views
# ---------------------------------------------------------------------------


def test_byzantine_sets_nested_in_fraction():
    """A larger swept fraction grows the attacker set, never reshuffles it."""
    fk = faults.fault_base_key(0)
    prev = np.zeros(64, bool)
    for frac in (0.05, 0.1, 0.3, 0.6, 1.0):
        cur = np.asarray(faults.byzantine_set(fk, 64, frac))
        assert (prev <= cur).all(), f"set not nested at frac={frac}"
        prev = cur
    assert prev.all()  # frac=1.0 marks everyone


def test_cohort_fault_draw_is_rows_of_full_draw():
    """A K < M cohort sees exactly the full population's fault trace rows."""
    cfg = _adsgd(byzantine_frac=0.4, fault_rate=0.3, erasure_prob=0.2)
    sch = get_scheme(cfg, 97, 4)
    key = jax.random.fold_in(jax.random.PRNGKey(1003), faults.SALT_FAULT)
    full = sch.fault_draw(key, 3, 10)
    cohort = jnp.asarray([1, 4, 7, 9])
    sub = sch.cohort_fault_draw(key, 3, cohort, 10)
    for name in ("byz", "poison", "stale", "dropout", "erased"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name))[np.asarray(cohort)],
            np.asarray(getattr(sub, name)), err_msg=name)


def test_fault_draw_validates_kind_and_attack():
    cfg = _adsgd()
    with pytest.raises(ValueError, match="fault_kind"):
        faults.fault_draw(faults.fault_base_key(0), jax.random.PRNGKey(0),
                          4, byzantine_frac=0.0, fault_rate=0.0,
                          erasure_prob=0.0, fault_kind="gamma_ray")
    draw = faults.fault_draw(faults.fault_base_key(0), jax.random.PRNGKey(0),
                             4, byzantine_frac=1.0, fault_rate=0.0,
                             erasure_prob=0.0)
    with pytest.raises(ValueError, match="byz_attack"):
        faults.apply_gradient_faults(jnp.ones((4, 3)), draw,
                                     byz_attack="telepathy")
    del cfg


# ---------------------------------------------------------------------------
# robust aggregators: bounds, invariances, degradation
# ---------------------------------------------------------------------------


def _rand_frames(seed, m=9, s=7):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(m, s)),
                       jnp.float32)


def test_trimmed_mean_bounded_by_live_minmax():
    frames = _rand_frames(0)
    alive = jnp.asarray([1, 1, 1, 0, 1, 1, 0, 1, 1], bool)
    out = np.asarray(aggregators.trimmed_mean(frames, alive, 0.2))
    live = np.asarray(frames)[np.asarray(alive)]
    assert (out >= live.min(axis=0) - 1e-6).all()
    assert (out <= live.max(axis=0) + 1e-6).all()


def test_trimmed_mean_permutation_invariant():
    frames = _rand_frames(1)
    alive = jnp.ones(frames.shape[0], bool)
    perm = jnp.asarray(np.random.default_rng(2).permutation(frames.shape[0]))
    a = np.asarray(aggregators.trimmed_mean(frames, alive, 0.25))
    b = np.asarray(aggregators.trimmed_mean(frames[perm], alive[perm], 0.25))
    np.testing.assert_array_equal(a, b)


def test_trimmed_mean_trim_zero_equals_mean():
    frames = _rand_frames(3)
    alive = jnp.ones(frames.shape[0], bool)
    out = np.asarray(aggregators.trimmed_mean(frames, alive, 0.0))
    np.testing.assert_allclose(out, np.asarray(frames).mean(axis=0),
                               rtol=1e-5, atol=1e-6)


def test_trimmed_mean_ignores_dead_row_outliers():
    """Dead rows sort to +inf and must never enter the trim window."""
    frames = _rand_frames(4, m=6)
    poisoned = frames.at[2].set(1e30).at[5].set(-1e30)
    alive = jnp.asarray([1, 1, 0, 1, 1, 0], bool)
    a = np.asarray(aggregators.trimmed_mean(frames, alive, 0.2))
    b = np.asarray(aggregators.trimmed_mean(poisoned, alive, 0.2))
    np.testing.assert_array_equal(a, b)


def test_median_matches_numpy_on_live_rows():
    frames = _rand_frames(5, m=7)
    alive = jnp.asarray([1, 1, 1, 0, 1, 1, 0], bool)
    out = np.asarray(aggregators.median(frames, alive))
    ref = np.median(np.asarray(frames)[np.asarray(alive)], axis=0)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-7)


def test_norm_cap_passthrough_is_bitwise_for_equal_norms():
    """Equal-norm honest rows with cap >= 1: scale is exactly 1.0."""
    rng = np.random.default_rng(6)
    rows = rng.normal(size=(5, 8)).astype(np.float32)
    rows /= np.linalg.norm(rows, axis=1, keepdims=True)
    frames = jnp.asarray(rows)
    alive = jnp.ones(5, bool)
    out = np.asarray(aggregators.norm_capped_sum(frames, alive, 1.5))
    np.testing.assert_array_equal(out, np.asarray(jnp.sum(frames, axis=0)))


def test_norm_cap_bounds_single_row_influence():
    """One huge row moves the sum by at most cap * median live norm."""
    rng = np.random.default_rng(7)
    rows = rng.normal(size=(9, 7)).astype(np.float32)
    rows /= np.linalg.norm(rows, axis=1, keepdims=True)  # median norm = 1
    frames = jnp.asarray(rows)
    boosted = frames.at[0].multiply(1e6)
    alive = jnp.ones(9, bool)
    out = np.asarray(aggregators.norm_capped_sum(boosted, alive, 1.5))
    honest = np.asarray(frames)[1:].sum(axis=0)  # scale exactly 1.0
    assert np.linalg.norm(out - honest) <= 1.5 * 1.0001


def test_norm_cap_zeroes_nonfinite_rows():
    frames = _rand_frames(8, m=6)
    poisoned = frames.at[1].set(jnp.nan).at[4].set(jnp.inf)
    alive = jnp.ones(6, bool)
    out = np.asarray(aggregators.norm_capped_sum(poisoned, alive, 10.0))
    assert np.isfinite(out).all()
    keep = np.asarray(frames)[[0, 2, 3, 5]]
    np.testing.assert_allclose(out, keep.sum(axis=0), rtol=1e-5, atol=1e-6)


def test_robust_combine_unknown_aggregator_raises():
    with pytest.raises(ValueError, match="aggregator"):
        aggregators.robust_combine(jnp.ones((3, 4)), jnp.ones(3, bool), 3.0,
                                   aggregator="blockchain")


def test_clip_frame_power_caps_energy_and_passes_honest_rows():
    frames = jnp.asarray([[3.0, 4.0], [30.0, 40.0]])  # energies 25, 2500
    out = np.asarray(aggregators.clip_frame_power(frames, 100.0))
    np.testing.assert_array_equal(out[0], np.asarray(frames)[0])  # scale 1.0
    np.testing.assert_allclose(float(np.sum(out[1] ** 2)), 100.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# bitwise neutrality of the robust path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mk", [_adsgd, _ddsgd], ids=["analog", "digital"])
def test_robust_flag_with_zero_rates_is_bitwise_noop(data, mk):
    """robust=True + all rates zero + defences off == the default path."""
    (xd, yd), (xte, yte) = data
    r0 = run_compiled(xd, yd, xte, yte, mk(), STEPS)
    r1 = run_compiled(xd, yd, xte, yte, mk(robust=True), STEPS)
    np.testing.assert_array_equal(np.asarray(r0.losses),
                                  np.asarray(r1.losses))
    np.testing.assert_array_equal(np.asarray(r0.accs), np.asarray(r1.accs))


# ---------------------------------------------------------------------------
# fault semantics through the drivers
# ---------------------------------------------------------------------------


def _one_round(cfg, grads, deltas, t=0):
    sch = get_scheme(cfg, grads.shape[1], grads.shape[0])
    ctx = MACContext(m=grads.shape[0], fading=cfg.fading, csi=sch.csi)
    key = round_keys(STEPS)[t]
    return round_masked(sch, grads, deltas, t, key,
                        jnp.ones(grads.shape[0], jnp.float32), ctx)


def test_stale_fault_equals_zero_gradients():
    """fault_kind=stale at rate 1 == every device sending g=0 (EF replay)."""
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(M, 64)), jnp.float32)
    deltas = jnp.asarray(rng.normal(size=(M, 64)), jnp.float32)
    g1, d1, _ = _one_round(_ddsgd(fault_rate=1.0, fault_kind="stale"),
                           grads, deltas)
    g2, d2, _ = _one_round(_ddsgd(robust=True), jnp.zeros_like(grads),
                           deltas)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_dropout_fault_banks_whole_update_digital():
    """A dropped digital device banks g + delta (silent_state) untransmitted."""
    rng = np.random.default_rng(1)
    grads = jnp.asarray(rng.normal(size=(M, 64)), jnp.float32)
    deltas = jnp.asarray(rng.normal(size=(M, 64)), jnp.float32)
    ghat, new_deltas, _ = _one_round(
        _ddsgd(fault_rate=1.0, fault_kind="dropout"), grads, deltas)
    np.testing.assert_array_equal(np.asarray(ghat),
                                  np.zeros_like(np.asarray(ghat)))
    np.testing.assert_allclose(np.asarray(new_deltas),
                               np.asarray(grads + deltas), rtol=1e-6)


def test_full_erasure_freezes_training(data):
    """erasure_prob=1: every digital packet is lost, the model never moves."""
    (xd, yd), (xte, yte) = data
    run = run_compiled(xd, yd, xte, yte, _ddsgd(erasure_prob=1.0), STEPS)
    assert np.ptp(np.asarray(run.losses)) == 0.0


def test_nan_frame_faults_reach_the_mac(data):
    """Poisoned frames survive sparsification: unguarded runs go non-finite."""
    (xd, yd), (xte, yte) = data
    for mk in (_adsgd, _ddsgd):
        run = run_compiled(xd, yd, xte, yte,
                           mk(fault_rate=0.4, fault_kind="nan"), STEPS)
        assert not np.isfinite(np.asarray(run.losses)).all(), mk.__name__


def test_fault_metrics_reported(data):
    (xd, yd), (xte, yte) = data
    run = run_compiled(xd, yd, xte, yte,
                       _adsgd(byzantine_frac=0.4, fault_rate=0.3,
                              fault_kind="dropout"), STEPS, eval_every=1)
    byz = [m["byz_frac"] for m in run.metrics]
    hit = [m["fault_frac"] for m in run.metrics]
    assert max(byz) > 0 and max(hit) > 0


# ---------------------------------------------------------------------------
# guardrails
# ---------------------------------------------------------------------------


def test_guard_skips_nonfinite_rounds(data):
    """The skip rail keeps a NaN-poisoned run finite end to end."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(fault_rate=0.4, fault_kind="nan")
    run = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=1,
                       guard=guards.GuardConfig(skip_nonfinite=True))
    assert np.isfinite(np.asarray(run.losses)).all()
    assert sum(m["guard_skipped"] for m in run.metrics) >= 1


def test_guard_zero_faults_keeps_training(data):
    """With nothing to guard against, a guarded run still trains."""
    (xd, yd), (xte, yte) = data
    plain = run_compiled(xd, yd, xte, yte, _adsgd(), STEPS)
    guarded = run_compiled(xd, yd, xte, yte, _adsgd(), STEPS,
                           guard=guards.GuardConfig(skip_nonfinite=True))
    assert sum(m["guard_skipped"] for m in guarded.metrics) == 0
    np.testing.assert_allclose(np.asarray(guarded.losses),
                               np.asarray(plain.losses), rtol=1e-5)


def test_divergence_backoff_reduces_lr_scale(data):
    """An aggressive divergence threshold fires the backoff + cooldown."""
    (xd, yd), (xte, yte) = data
    g = guards.GuardConfig(divergence_factor=1e-4, lr_backoff=0.5,
                           cooldown=2)
    run = run_compiled(xd, yd, xte, yte, _adsgd(), STEPS, eval_every=1,
                       guard=g)
    assert sum(m["guard_backoff"] for m in run.metrics) >= 1
    assert run.metrics[-1]["guard_lr_scale"] < 1.0
    # cooldown: backoffs cannot fire on consecutive rounds
    fires = [m["guard_backoff"] for m in run.metrics]
    assert all(not (a and b) for a, b in zip(fires, fires[1:]))


def test_update_clip_bounds_applied_update():
    """The clamp rail caps the decoded update's L2 norm before Adam."""
    from repro.optim.optim import Optimizer
    from repro.train.paper_repro import init_linear

    params = init_linear(8, 3, jax.random.PRNGKey(0))
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    opt = Optimizer(name="sgd", lr=1.0)
    ghat = jnp.full_like(flat, 100.0)
    g = guards.GuardConfig(update_clip=1.0, skip_nonfinite=False)
    p1, _, _, _, _, _ = guarded_step_ref = guards.guarded_step(
        g, guards.init_guard_state(), opt, params, opt.init(params), ghat,
        unravel, extras=(), old_extras=(), loss_fn=lambda p: jnp.float32(0.0))
    moved = jax.flatten_util.ravel_pytree(p1)[0] - flat
    np.testing.assert_allclose(float(jnp.linalg.norm(moved)), 1.0, rtol=1e-5)
    del guarded_step_ref


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


def test_checkpointed_run_bitwise_equals_plain(data, tmp_path):
    """Segmenting the scan (with a guard in the carry) changes nothing."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(byzantine_frac=0.25)
    g = guards.GuardConfig(skip_nonfinite=True)
    full = run_compiled(xd, yd, xte, yte, cfg, STEPS, guard=g)
    seg = run_compiled(xd, yd, xte, yte, cfg, STEPS, guard=g,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2)
    np.testing.assert_array_equal(np.asarray(full.losses),
                                  np.asarray(seg.losses))
    np.testing.assert_array_equal(np.asarray(full.accs),
                                  np.asarray(seg.accs))


def test_interrupted_resume_bitwise_equals_uninterrupted(data, tmp_path):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(byzantine_frac=0.25)
    full = run_compiled(xd, yd, xte, yte, cfg, STEPS)
    part = run_compiled(xd, yd, xte, yte, cfg, STEPS,
                        checkpoint_dir=str(tmp_path), checkpoint_every=2,
                        stop_after_step=3)
    assert part is None  # interrupted at the first boundary past step 3
    assert os.path.exists(os.path.join(str(tmp_path), "engine_ckpt.npz"))
    res = run_compiled(xd, yd, xte, yte, cfg, STEPS,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       resume=True)
    np.testing.assert_array_equal(np.asarray(full.losses),
                                  np.asarray(res.losses))
    np.testing.assert_array_equal(np.asarray(full.accs),
                                  np.asarray(res.accs))
    for k in full.metrics[-1]:
        np.testing.assert_array_equal(
            np.asarray([m[k] for m in full.metrics]),
            np.asarray([m[k] for m in res.metrics]), err_msg=k)


def test_resume_without_snapshot_starts_fresh(data, tmp_path):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    full = run_compiled(xd, yd, xte, yte, cfg, STEPS)
    res = run_compiled(xd, yd, xte, yte, cfg, STEPS,
                       checkpoint_dir=str(tmp_path), checkpoint_every=4,
                       resume=True)
    np.testing.assert_array_equal(np.asarray(full.losses),
                                  np.asarray(res.losses))


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------


def test_byzantine_sweep_matches_per_point_runs(data):
    """A vmapped byzantine_frac grid == the per-point compiled runs."""
    (xd, yd), (xte, yte) = data
    base = _ddsgd(aggregator="norm_cap", norm_cap=1.5, byz_scale=20.0)
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"byzantine_frac": [0.0, 0.25]}, steps=STEPS,
                    eval_every=1)
    for bf in (0.0, 0.25):
        pt = run_compiled(xd, yd, xte, yte,
                          dataclasses.replace(base, robust=True,
                                              byzantine_frac=bf), STEPS,
                          eval_every=1)
        rec = res.record(byzantine_frac=bf)
        np.testing.assert_allclose(rec["losses"], np.asarray(pt.losses),
                                   rtol=1e-6)


def test_robust_axes_are_registered_and_validated(data):
    (xd, yd), (xte, yte) = data
    for ax in ROBUST_VMAP_AXES:
        assert hasattr(get_scheme(_adsgd(), 10, M), ax), ax
    with pytest.raises(KeyError, match="unknown sweep axis"):
        run_sweep((xd, yd), (xte, yte), _adsgd(),
                  {"byzantine_fraction": [0.1]}, steps=2)
