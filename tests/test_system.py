"""End-to-end behaviour: the paper's federated pipeline on the MNIST
surrogate — scheme orderings and robustness claims in miniature (§VI)."""
import pytest

from repro.configs.base import OTAConfig
from repro.data.synthetic import federated_split, make_classification
from repro.train.paper_repro import run_federated


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(n_train=6000, n_test=1500,
                                                 noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=10, b=400, iid=True, seed=0)
    return xd, yd, xte, yte


STEPS = 40


def _final_acc(data, scheme, **kw):
    xd, yd, xte, yte = data
    base = dict(s_frac=0.5, k_frac=0.5, p_avg=500.0, total_steps=STEPS,
                projection="dense", amp_iters=15, mean_removal_steps=5)
    base.update(kw)
    ota = OTAConfig(scheme=scheme, **base)
    run = run_federated(xd, yd, xte, yte, ota, steps=STEPS, lr=2e-3,
                        eval_every=STEPS)
    return run.accs[-1]


def test_adsgd_learns_and_tracks_ideal(data):
    acc_ideal = _final_acc(data, "ideal")
    acc_adsgd = _final_acc(data, "a_dsgd")
    assert acc_ideal > 0.55
    assert acc_adsgd > 0.5
    assert acc_ideal - acc_adsgd < 0.2      # paper Fig. 2: small gap


def test_adsgd_beats_ddsgd_at_low_power(data):
    """Paper Fig. 4/6: analog wins at low P-bar (digital budget collapses)."""
    acc_a = _final_acc(data, "a_dsgd", p_avg=1.0)
    acc_d = _final_acc(data, "d_dsgd", p_avg=1.0)
    assert acc_a > acc_d, (acc_a, acc_d)


def test_noniid_degrades_adsgd_mildly(data):
    xd, yd, xte, yte = data
    (xtr, ytr), _ = make_classification(n_train=6000, n_test=10, noise=2.0,
                                        seed=3)
    xd_n, yd_n = federated_split(xtr, ytr, m=10, b=400, iid=False, seed=0)
    ota = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.5, p_avg=500.0,
                    total_steps=STEPS, projection="dense", amp_iters=15,
                    mean_removal_steps=5)
    acc_iid = run_federated(xd, yd, xte, yte, ota, steps=STEPS, lr=2e-3,
                            eval_every=STEPS).accs[-1]
    acc_non = run_federated(xd_n, yd_n, xte, yte, ota, steps=STEPS, lr=2e-3,
                            eval_every=STEPS).accs[-1]
    assert acc_non > acc_iid - 0.25         # robust to bias (paper Fig. 2b)
