"""Channel-model subsystem tests: fading processes, CSI models, the two
imperfect-CSI schemes (csi_err / blind), and the truncated-inversion edge
cases (follow-ups arXiv:1907.09769 / arXiv:1907.03909)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core import channel, fading
from repro.core.schemes import MACContext, get_scheme, round_simulated

D, M = 256, 6


def _cfg(scheme="a_dsgd_fading", **kw):
    base = dict(scheme=scheme, s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=10, projection="dense", amp_iters=8,
                mean_removal_steps=2)
    base.update(kw)
    return OTAConfig(**base)


# ---------------------------------------------------------------------------
# truncated channel inversion: edge cases (satellite task)
# ---------------------------------------------------------------------------


def test_truncated_inversion_threshold_exactly_at_gain():
    """|h| == threshold is *inclusive*: the device transmits (h >= thr)."""
    thr = 0.5
    h = jnp.asarray([thr, np.nextafter(thr, 0.0, dtype=np.float32),
                     np.nextafter(thr, 1.0, dtype=np.float32)])
    p, active = channel.truncated_inversion_power(h, thr)
    np.testing.assert_array_equal(np.asarray(active), [True, False, True])
    assert float(p[0]) == pytest.approx(thr * thr)
    assert float(p[1]) == 0.0


def test_truncated_inversion_all_deep_fade_zero_transmit_set():
    """Every device below threshold: the transmit set is empty (all factors
    0, all masks False) and a full round degrades to decoding pure AWGN
    while every device banks its whole update in the error state."""
    h = jnp.full((M,), 0.01)
    p, active = channel.truncated_inversion_power(h, 0.3)
    assert not bool(jnp.any(active))
    np.testing.assert_array_equal(np.asarray(p), np.zeros(M))

    cfg = _cfg(fading_threshold=1e9)
    sch = get_scheme(cfg, D, M)
    grads = jax.random.normal(jax.random.PRNGKey(0), (M, D))
    deltas = jnp.zeros((M, D))
    ghat, nd, met = round_simulated(sch, grads, deltas, 0,
                                    jax.random.PRNGKey(1))
    assert float(met["active_frac"]) == 0.0
    # silent devices accumulate g + Delta (here Delta = 0)
    np.testing.assert_allclose(np.asarray(nd), np.asarray(grads), rtol=1e-6)
    assert bool(jnp.all(jnp.isfinite(ghat)))


def test_truncated_inversion_huge_gain_power_sanity():
    """h -> huge stays sane: the received-power factor is exactly h^2 (the
    transmit side pre-inverts, so transmit power never exceeds P_t) and
    stays finite up to the f32 horizon."""
    h = jnp.asarray([1.0, 1e3, 1e18])
    p, active = channel.truncated_inversion_power(h, 0.3)
    assert bool(jnp.all(active))
    np.testing.assert_allclose(np.asarray(p), np.asarray(h) ** 2, rtol=1e-6)
    assert bool(jnp.all(jnp.isfinite(p)))
    # and the frame a device builds under that factor carries P_t * h^2
    g = jax.random.normal(jax.random.PRNGKey(2), (32,))
    frame, _ = channel.make_frame(g, 100.0 * 1e6, False)   # P_t * h^2, h=1e3
    np.testing.assert_allclose(float(channel.frame_power(frame)), 1e8,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# fading processes
# ---------------------------------------------------------------------------


def _draws(process, steps, m=512, rho=0.9, window=64):
    spec = fading.FadingSpec(process=process, window=window)
    fkey = fading.fading_base_key(0)
    out = []
    for t in range(steps):
        rkey = jax.random.fold_in(jax.random.PRNGKey(100 + t), 2)
        re, im = fading.process_gains(spec, fkey, rkey, t, m, rho=rho)
        out.append(np.asarray(re) + 1j * np.asarray(im))
    return np.stack(out)                                   # (steps, m)


def test_static_process_is_block_flat():
    h = _draws("static", 5)
    for t in range(1, 5):
        np.testing.assert_array_equal(h[t], h[0])


def test_iid_process_redraws_and_matches_legacy_rayleigh():
    h = _draws("iid", 3)
    assert not np.array_equal(h[0], h[1])
    # bitwise the legacy channel.rayleigh_gains magnitudes
    key = jax.random.fold_in(jax.random.PRNGKey(100), 2)
    spec = fading.FadingSpec(process="iid")
    re, im = fading.process_gains(spec, fading.fading_base_key(0), key, 0, 16)
    np.testing.assert_array_equal(np.asarray(fading.magnitude(re, im)),
                                  np.asarray(channel.rayleigh_gains(key, 16)))


def test_gauss_markov_stationary_and_correlated():
    """Unit marginal variance; autocorrelation ~ rho^|dt| and decaying."""
    rho = 0.8
    h = _draws("gauss_markov", 12, m=4096, rho=rho)
    var = np.mean(np.abs(h) ** 2)
    assert 0.9 < var < 1.1
    corr = [np.mean((h[0] * np.conj(h[dt])).real) / var for dt in (1, 4, 8)]
    assert corr[0] == pytest.approx(rho, abs=0.1)
    assert corr[0] > corr[1] > corr[2] - 0.05
    assert corr[2] < 0.35


def test_gauss_markov_rho_is_traced_data():
    """rho enters only as a traced weight vector -> vmappable axis."""
    spec = fading.FadingSpec(process="gauss_markov", window=16)
    fkey = fading.fading_base_key(0)
    rkey = jax.random.PRNGKey(3)

    def f(rho):
        re, im = fading.process_gains(spec, fkey, rkey, 2, 8, rho=rho)
        return re
    res = jax.vmap(f)(jnp.asarray([0.1, 0.9]))
    assert res.shape == (2, 8)
    assert not np.array_equal(np.asarray(res[0]), np.asarray(res[1]))


# ---------------------------------------------------------------------------
# CSI models
# ---------------------------------------------------------------------------


def test_csi_estimate_zero_error_is_exact():
    re, im = fading.complex_normals(jax.random.PRNGKey(0), 64)
    er, ei = fading.csi_estimate(re, im, jax.random.PRNGKey(1), 0.0)
    np.testing.assert_array_equal(np.asarray(er), np.asarray(re))
    np.testing.assert_array_equal(np.asarray(ei), np.asarray(im))
    g = fading.misalignment_gain(re, im, er, ei, 0.0)
    np.testing.assert_array_equal(np.asarray(g), np.ones(64, np.float32))


def test_csi_estimate_error_degrades_alignment():
    re, im = fading.complex_normals(jax.random.PRNGKey(0), 4096)
    er, ei = fading.csi_estimate(re, im, jax.random.PRNGKey(1), 0.5)
    g = fading.misalignment_gain(re, im, er, ei, 0.5)
    # Re(h / h_hat) scatters around ~1 with heavy spread; no exact ones
    assert float(jnp.mean(jnp.abs(g - 1.0))) > 0.05
    assert not bool(jnp.all(g == 1.0))


def test_blind_combiner_channel_hardening():
    """As K grows the combiner gains -> 1 and the noise scale -> 0 — the
    blind MAC hardens into the ideal link (1907.03909's asymptotic)."""
    m = 8
    stats = {}
    for k in (8, 128, 2048):
        re, im = fading.complex_normals(jax.random.PRNGKey(5), m * k)
        gain, ns = fading.blind_combiner_stats(re.reshape(m, k),
                                               im.reshape(m, k))
        stats[k] = (float(jnp.mean(jnp.abs(gain - 1.0))), float(ns))
    assert stats[8][0] > stats[128][0] > stats[2048][0]
    assert stats[2048][0] < 0.1
    assert stats[8][1] > stats[128][1] > stats[2048][1]
    assert stats[2048][1] < 0.05


# ---------------------------------------------------------------------------
# the imperfect-CSI schemes on the generic drivers
# ---------------------------------------------------------------------------


def test_csi_err_scheme_recovery_degrades_with_error():
    """Gradient-recovery error grows with the CSI error variance, averaged
    over channel seeds (a single draw can swing either way: the estimate's
    |h_hat|^2 power boost sometimes offsets the misalignment).  The
    zero-error point is the perfect-CSI scheme bitwise, which
    tests/test_schemes.py pins against the golden."""
    grads = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(7), (D,)), (M, D))
    errs = {}
    for ev in (0.0, 1.0):
        sch = get_scheme(_cfg("a_dsgd_csi_err", csi_err_var=ev,
                              fading_threshold=0.2), D, M)
        se = 0.0
        for s in range(8):
            deltas = jnp.zeros((M, D))
            for t in range(3):
                ghat, deltas, _ = round_simulated(
                    sch, grads, deltas, t, jax.random.PRNGKey(37 * s + t))
                se += float(jnp.sum((ghat - grads[0]) ** 2))
        errs[ev] = se
    assert errs[1.0] > 1.1 * errs[0.0]


def test_blind_scheme_all_devices_transmit():
    sch = get_scheme(_cfg("a_dsgd_blind", ps_antennas=16), D, M)
    grads = jax.random.normal(jax.random.PRNGKey(8), (M, D))
    ghat, nd, met = round_simulated(sch, grads, jnp.zeros((M, D)), 0,
                                    jax.random.PRNGKey(9))
    assert float(met["active_frac"]) == 1.0
    assert float(met["noise_scale"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(ghat)))


def test_blind_many_antennas_approaches_awgn_adsgd():
    """With a huge antenna array the blind round converges to the plain
    AWGN A-DSGD round: gains -> 1, noise enhancement -> 0 (< sigma2)."""
    grads = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(7), (D,)), (M, D))
    deltas = jnp.zeros((M, D))
    ref_sch = get_scheme(_cfg("a_dsgd"), D, M)
    ghat_ref, _, _ = round_simulated(ref_sch, grads, deltas, 0,
                                     jax.random.PRNGKey(11))
    blind = get_scheme(_cfg("a_dsgd_blind", ps_antennas=4096), D, M)
    ghat_b, _, met = round_simulated(blind, grads, deltas, 0,
                                     jax.random.PRNGKey(11))
    assert float(met["noise_scale"]) < 0.1
    # both reconstruct the same (shared) gradient to similar accuracy
    err_ref = float(jnp.linalg.norm(ghat_ref - grads[0]))
    err_b = float(jnp.linalg.norm(ghat_b - grads[0]))
    assert err_b < 1.5 * err_ref + 1e-3


def test_blind_channel_draw_mask_excludes_phantom_devices():
    """m_active padding: masked-out devices' channel rows must not enter
    the blind PS combiner — the masked draw equals the combiner statistics
    of the live subset, and an all-ones mask is bitwise the unmasked draw."""
    sch = get_scheme(_cfg("a_dsgd_blind", ps_antennas=8), D, M)
    key = jax.random.PRNGKey(3)
    full = sch.channel_draw(key, 0, M)
    ones = sch.channel_draw(key, 0, M, mask=jnp.ones((M,), bool))
    np.testing.assert_array_equal(np.asarray(full.gain),
                                  np.asarray(ones.gain))
    np.testing.assert_array_equal(np.asarray(full.noise_scale),
                                  np.asarray(ones.noise_scale))
    mask = jnp.arange(M) < 2
    masked = sch.channel_draw(key, 0, M, mask=mask)
    # reproduce by hand: zero the phantom rows, recompute the stats
    k_ant = sch.fading_spec.ps_antennas
    re, im = sch.gains(key, 0, M * k_ant)
    live = mask.astype(jnp.float32)[:, None]
    g_ref, ns_ref = fading.blind_combiner_stats(
        re.reshape(M, k_ant) * live, im.reshape(M, k_ant) * live)
    np.testing.assert_array_equal(np.asarray(masked.gain),
                                  np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(masked.noise_scale),
                                  np.asarray(ns_ref))
    # fewer live transmitters -> strictly less combiner interference
    assert float(masked.noise_scale) < float(full.noise_scale)


@pytest.mark.parametrize("scheme", ["a_dsgd_csi_err", "a_dsgd_blind"])
def test_imperfect_csi_schemes_on_sharded_drivers(scheme):
    """Both new schemes run through round_sharded and the slice driver
    (sharded_round) — the channel draw is evaluated from the shared round
    key and indexed per device, so it works at any mesh size."""
    from jax.sharding import PartitionSpec as P
    from repro.core import distributed
    from repro.sharding import shard_map

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("dev",))
    grads = jax.random.normal(jax.random.PRNGKey(1), (n_dev, D))
    deltas = jnp.zeros((n_dev, D))
    cfg = _cfg(scheme, projection="blocked", block_size=64, amp_iters=4,
               csi_err_var=0.2, ps_antennas=8, fading_threshold=0.1)
    sch = get_scheme(cfg, D, n_dev)
    ctx = MACContext(m=n_dev, device_axes=("dev",), d_pad=D,
                     fading="rayleigh", csi=sch.csi)

    def psum_body(g, dl):
        ghat, _, _ = round_sharded_wrap(g.reshape(-1), dl.reshape(-1))
        return ghat

    from repro.core import schemes as schemes_mod

    def round_sharded_wrap(g, dl):
        return schemes_mod.round_sharded(sch, g, dl, 0,
                                         jax.random.PRNGKey(5), ctx)

    ghat = shard_map(psum_body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                     out_specs=P(), axis_names={"dev"},
                     check_vma=False)(grads, deltas)
    assert bool(jnp.all(jnp.isfinite(ghat)))

    def slice_body(g, dl):
        ghat_s, _, _ = distributed.sharded_round(sch, g.reshape(-1),
                                                 dl.reshape(-1), 0,
                                                 jax.random.PRNGKey(5), ctx)
        return ghat_s.reshape(1, -1)

    ghat_s = shard_map(slice_body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                       out_specs=P("dev"), axis_names={"dev"},
                       check_vma=False)(grads, deltas)
    assert bool(jnp.all(jnp.isfinite(ghat_s)))


def test_unknown_fading_process_raises():
    with pytest.raises(ValueError, match="unknown fading_process"):
        get_scheme(_cfg(fading_process="warp"), D, M).fading_spec
