"""Scheme registry + refactor-parity tests.

The goldens in tests/golden/simulated_parity.npz were generated from the
pre-registry implementation (the ``Aggregator.encode`` if/elif chain) at a
fixed seed; asserting bitwise equality here proves the ``Scheme`` registry
refactor changed no numerics (see tests/golden/make_golden.py).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core import schemes
from repro.core.schemes import (
    MACContext, PAPER_SCHEMES, SCHEME_REGISTRY, SCHEMES, Scheme, get_scheme,
    register_scheme, round_simulated,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from tests.golden.parity_cases import PARITY_CASES  # noqa: E402

D, M = 256, 6

_GOLDEN = np.load(os.path.join(os.path.dirname(__file__), "golden",
                               "simulated_parity.npz"))


# ---------------------------------------------------------------------------
# registry round-trip
# ---------------------------------------------------------------------------


def test_registry_contains_all_paper_schemes_plus_fading():
    for name in PAPER_SCHEMES:
        assert name in SCHEME_REGISTRY
    assert "a_dsgd_fading" in SCHEME_REGISTRY
    assert set(SCHEMES) == set(SCHEME_REGISTRY)


@pytest.mark.parametrize("name", sorted(SCHEME_REGISTRY))
def test_get_scheme_roundtrip(name):
    cfg = OTAConfig(scheme=name, total_steps=10)
    sch = get_scheme(cfg, D, M)
    assert isinstance(sch, SCHEME_REGISTRY[name])
    assert sch.name == name
    assert sch.d == D and sch.m == M
    state = sch.init_state()
    assert state.shape == (D,)
    assert int(sch.channel_dim()) > 0


def test_get_scheme_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown scheme"):
        get_scheme(OTAConfig(scheme="carrier_pigeon"), D, M)


def test_legacy_fading_flag_promotes_to_fading_scheme():
    cfg = OTAConfig(scheme="a_dsgd", fading="rayleigh", projection="dense",
                    total_steps=10)
    assert type(get_scheme(cfg, D, M)).__name__ == "ADSGDFadingScheme"


def test_register_custom_scheme_runs_on_generic_driver():
    """The ~10-line extension from the README, end to end."""

    @register_scheme("_test_half")
    class HalfScheme(Scheme):
        def channel_dim(self, d=None):
            return self.d

        def encode(self, g, state, step, key, ctx=None):
            return 0.5 * g.astype(jnp.float32), state, {}

    try:
        sch = get_scheme(OTAConfig(scheme="_test_half", total_steps=5), D, M)
        grads = jnp.ones((M, D))
        ghat, _, _ = round_simulated(sch, grads, jnp.zeros((M, D)), 0,
                                     jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(ghat), 0.5, rtol=1e-6)
    finally:
        del SCHEME_REGISTRY["_test_half"]


# ---------------------------------------------------------------------------
# fixed-seed parity with the pre-refactor implementation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", sorted(PARITY_CASES))
def test_simulated_driver_bitwise_parity(case):
    cfg = PARITY_CASES[case]
    grads = jnp.asarray(_GOLDEN["grads"])
    sch = get_scheme(cfg, D, M)
    ghat, nd, _ = round_simulated(sch, grads, jnp.zeros((M, D)), 0,
                                  jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(ghat), _GOLDEN[f"{case}__ghat"])
    np.testing.assert_array_equal(np.asarray(nd), _GOLDEN[f"{case}__deltas"])


def test_csi_err_zero_is_fading_golden():
    """a_dsgd_csi_err at zero estimation error degrades *bitwise* to
    a_dsgd_fading: the estimate h_hat = h + 0*e is IEEE-exact and the
    misalignment gain is exactly 1.0, so the two goldens must be the same
    arrays (acceptance criterion of the fading-suite PR)."""
    np.testing.assert_array_equal(_GOLDEN["a_dsgd_csi_err0__ghat"],
                                  _GOLDEN["a_dsgd_rayleigh__ghat"])
    np.testing.assert_array_equal(_GOLDEN["a_dsgd_csi_err0__deltas"],
                                  _GOLDEN["a_dsgd_rayleigh__deltas"])


# ---------------------------------------------------------------------------
# driver parity: ideal scheme, simulated == sharded (single host)
# ---------------------------------------------------------------------------


def test_ideal_simulated_matches_sharded_single_host():
    from jax.sharding import PartitionSpec as P
    from repro.sharding import shard_map

    cfg = OTAConfig(scheme="ideal", total_steps=10)
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("dev",))
    grads = jnp.asarray(_GOLDEN["grads"][:n_dev])
    deltas = jnp.zeros((n_dev, D))
    sch = get_scheme(cfg, D, n_dev)
    ghat_sim, _, _ = schemes.round_simulated(sch, grads, deltas, 0,
                                             jax.random.PRNGKey(3))

    ctx = MACContext(m=n_dev, device_axes=("dev",))

    def body(g, dl):
        ghat, nd, _ = schemes.round_sharded(sch, g.reshape(-1),
                                            dl.reshape(-1), 0,
                                            jax.random.PRNGKey(3), ctx)
        return ghat

    ghat_sh = shard_map(body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                        out_specs=P(), axis_names={"dev"},
                        check_vma=False)(grads, deltas)
    np.testing.assert_allclose(np.asarray(ghat_sim), np.asarray(ghat_sh),
                               rtol=1e-6, atol=1e-7)


def test_fading_reaches_sharded_drivers():
    """a_dsgd_fading is live on round_sharded and the slice driver: with an
    impossible fade threshold every device is silent, so the whole update
    accumulates into the error state (truncated inversion, follow-up [34])."""
    from jax.sharding import PartitionSpec as P
    from repro.core import distributed
    from repro.sharding import shard_map

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("dev",))
    grads = jnp.asarray(_GOLDEN["grads"][:n_dev])
    deltas = jnp.zeros((n_dev, D))
    cfg = OTAConfig(scheme="a_dsgd_fading", fading_threshold=1e9,
                    s_frac=0.5, k_frac=0.25, p_avg=500.0, total_steps=10,
                    projection="blocked", block_size=64, amp_iters=5)
    sch = get_scheme(cfg, D, n_dev)
    ctx = MACContext(m=n_dev, device_axes=("dev",), d_pad=D,
                     fading="rayleigh")

    def slice_body(g, dl):
        _, nd, _ = distributed.sharded_round(sch, g.reshape(-1),
                                             dl.reshape(-1), 0,
                                             jax.random.PRNGKey(5), ctx)
        return nd.reshape(1, -1)

    nd = shard_map(slice_body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                   out_specs=P("dev"), axis_names={"dev"},
                   check_vma=False)(grads, deltas)
    # silent device: Delta' = g + Delta (here Delta = 0)
    np.testing.assert_allclose(np.asarray(nd), np.asarray(grads), rtol=1e-6)

    def psum_body(g, dl):
        _, nd, _ = schemes.round_sharded(sch, g.reshape(-1), dl.reshape(-1),
                                         0, jax.random.PRNGKey(5), ctx)
        return nd.reshape(1, -1)

    nd2 = shard_map(psum_body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                    out_specs=P("dev"), axis_names={"dev"},
                    check_vma=False)(grads, deltas)
    np.testing.assert_allclose(np.asarray(nd2), np.asarray(grads), rtol=1e-6)


def test_ideal_slice_driver_matches_mean():
    """The generic slice driver (distributed.sharded_round) on one host."""
    from jax.sharding import PartitionSpec as P
    from repro.core import distributed
    from repro.sharding import shard_map

    cfg = OTAConfig(scheme="ideal", total_steps=10)
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("dev",))
    grads = jnp.asarray(_GOLDEN["grads"][:n_dev])
    deltas = jnp.zeros((n_dev, D))
    sch = get_scheme(cfg, D, n_dev)
    ctx = MACContext(m=n_dev, device_axes=("dev",), d_pad=D)

    def body(g, dl):
        ghat, nd, _ = distributed.sharded_round(sch, g.reshape(-1),
                                                dl.reshape(-1), 0,
                                                jax.random.PRNGKey(3), ctx)
        return ghat

    ghat = shard_map(body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                     out_specs=P(), axis_names={"dev"},
                     check_vma=False)(grads, deltas)
    np.testing.assert_allclose(np.asarray(ghat),
                               np.asarray(grads.mean(0)), rtol=1e-5)
