"""The CI benchmark regression gate, including the --strict vacuity check."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.check_regression import compare, main  # noqa: E402

KERNELS = {"entries": [
    {"size": 1024, "op": "topk", "path": "pallas", "us_per_call": 10.0},
    {"size": 4096, "op": "topk", "path": "pallas", "us_per_call": 40.0},
]}
SWEEPS = {"a_dsgd_us_per_round": 100.0, "d_dsgd_us_per_round": 80.0,
          "compiled_cold_us_per_round": 5e6, "label": "not-a-timing"}


def _entries(us_by_size):
    return {"entries": [dict(e, us_per_call=us_by_size[e["size"]])
                        for e in KERNELS["entries"]]}


def test_within_threshold_passes_and_regression_fails():
    assert compare(KERNELS, _entries({1024: 15.0, 4096: 60.0})) == 0
    assert compare(KERNELS, _entries({1024: 25.0, 4096: 60.0})) == 1


def test_missing_entry_warns_but_passes_unless_strict():
    fresh = {"entries": KERNELS["entries"][:1]}
    assert compare(KERNELS, fresh) == 0
    # partial match: strict is satisfied — at least one timing was compared
    assert compare(KERNELS, fresh, strict=True) == 0


def test_strict_fails_when_nothing_matches():
    """A wholesale schema/naming drift leaves the gate comparing nothing;
    --strict turns that silent vacuity into a failure."""
    renamed = {"entries": [dict(e, op="topk_v2") for e in KERNELS["entries"]]}
    assert compare(KERNELS, renamed) == 0  # non-strict: silently vacuous
    assert compare(KERNELS, renamed, strict=True) == 1
    # sweeps flavour: same rule, and ungated/non-timing keys don't count
    assert compare(SWEEPS, {"compiled_cold_us_per_round": 1.0,
                            "label": "x"}, strict=True) == 1
    # an empty baseline has nothing to gate: strict stays quiet
    assert compare({"entries": []}, renamed, strict=True) == 0


def test_sweep_baseline_key_absent_from_fresh_warns_and_skips(capsys):
    """A baseline timing with no counterpart in a fresh BENCH file (a
    figure was renamed or not rerun) is skipped with a WARNING, not
    failed — and the skip doesn't satisfy --strict on its own."""
    fresh = {"a_dsgd_us_per_round": 110.0}
    assert compare(SWEEPS, fresh) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "d_dsgd_us_per_round" in out
    # strict still passes: one real comparison happened
    assert compare(SWEEPS, fresh, strict=True) == 0
    # ...but a fresh file with *only* unmatched keys fails strict
    assert compare(SWEEPS, {"brand_new_us_per_round": 1.0},
                   strict=True) == 1


def test_main_parses_strict_flag(tmp_path):
    base = os.path.join(tmp_path, "base.json")
    fresh = os.path.join(tmp_path, "fresh.json")
    with open(base, "w") as fh:
        json.dump(KERNELS, fh)
    with open(fresh, "w") as fh:
        json.dump({"entries": [dict(e, op="renamed")
                               for e in KERNELS["entries"]]}, fh)
    assert main(["check_regression.py", base, fresh]) == 0
    assert main(["check_regression.py", "--strict", base, fresh]) == 1
    with open(fresh, "w") as fh:
        json.dump(KERNELS, fh)
    assert main(["check_regression.py", "--strict", base, fresh]) == 0
