"""Population engine: banked state, sampling, churn, stragglers, hierarchy.

The acceptance bar mirrors the engine's: a K == M cohort through the banked
population round must be *bitwise* the dense drivers (pinned by the
``population_full`` golden and by full-run parity with ``run_compiled``);
everything beyond that — cohort sampling, eviction, deadlines, edge sites —
is tested against its own contract.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core.schemes import MACContext, get_scheme
from repro.data.partition import population_partition
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import run_compiled, run_population_sweep
from repro.population import (
    CompiledPopulation, PopulationConfig, PopulationData,
    PopulationExperiment, gather_cohort, init_banks, population_round,
    run_population, sample_cohort, scatter_cohort, site_mac_sum,
)
from repro.population import churn, stragglers

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from tests.golden.parity_cases import PARITY_CASES  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulated_parity.npz")
STEPS, M, B = 6, 4, 64


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=800, n_test=300, dim=48, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=B, iid=True, seed=0)
    return (xd, yd), (xte, yte)


def _adsgd(**kw):
    base = dict(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=STEPS, projection="dense", amp_iters=6,
                mean_removal_steps=2)
    base.update(kw)
    return OTAConfig(**base)


# ---------------------------------------------------------------------------
# bitwise parity with the dense drivers
# ---------------------------------------------------------------------------


def test_population_round_full_cohort_matches_golden():
    """K == M through the banked round == the a_dsgd_dense golden, bitwise.

    bank_size 4 over M = 6 devices forces a 2-bank layout, so the gather /
    scatter addressing is genuinely exercised, not an identity."""
    g = np.load(GOLDEN)
    grads = jnp.asarray(g["grads"])
    m, d = grads.shape
    cfg = PARITY_CASES["a_dsgd_dense"]
    scheme = get_scheme(cfg, d, m)
    ctx = MACContext(m=m, fading=cfg.fading, csi=scheme.csi)
    cohort = jnp.arange(m, dtype=jnp.int32)
    ghat, banks, met = population_round(
        scheme, init_banks(m, 4, d), cohort, jnp.ones((m,), jnp.float32),
        grads, 0, jax.random.PRNGKey(11), ctx, m)
    np.testing.assert_array_equal(np.asarray(ghat), g["population_full__ghat"])
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, cohort)),
                                  g["population_full__deltas"])
    # and the population pin itself equals the dense-driver pin
    np.testing.assert_array_equal(g["population_full__ghat"],
                                  g["a_dsgd_dense__ghat"])
    np.testing.assert_array_equal(g["population_full__deltas"],
                                  g["a_dsgd_dense__deltas"])
    assert float(met["cohort_frac"]) == 1.0


def test_run_population_k_equals_m_matches_run_compiled(data):
    """Full-population sampling (K == M, no churn/stragglers) == the dense
    compiled engine, entry for entry."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    pop = PopulationConfig(m_total=M, k_cohort=M, bank_size=3)
    ref = run_compiled(xd, yd, xte, yte, cfg, steps=STEPS, lr=1e-3,
                       eval_every=2)
    eng = run_population(PopulationData.from_dense(xd, yd), xte, yte, cfg,
                         pop, steps=STEPS, lr=1e-3, eval_every=2)
    assert eng.accs == ref.accs
    assert eng.losses == ref.losses


# ---------------------------------------------------------------------------
# banked state
# ---------------------------------------------------------------------------


def test_banks_cold_gather_is_zero_and_roundtrips():
    banks = init_banks(8, 4, 3)
    cohort = jnp.asarray([1, 5, 6], jnp.int32)
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, cohort)),
                                  np.zeros((3, 3)))
    vals = jnp.arange(9.0).reshape(3, 3)
    banks = scatter_cohort(banks, cohort, vals)
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, cohort)),
                                  np.asarray(vals))
    # untouched devices still read cold
    np.testing.assert_array_equal(
        np.asarray(gather_cohort(banks, jnp.asarray([0, 2], jnp.int32))),
        np.zeros((2, 3)))


def test_banks_capacity_below_m_evicts_to_cold_state():
    """Direct-mapped eviction: device 9 claims device 1's slot (9 mod 8),
    and device 1 subsequently reads the cold state, not stale data."""
    banks = init_banks(8, 4, 2)
    one = jnp.asarray([1], jnp.int32)
    nine = jnp.asarray([9], jnp.int32)
    banks = scatter_cohort(banks, one, jnp.full((1, 2), 7.0))
    banks = scatter_cohort(banks, nine, jnp.full((1, 2), 3.0))
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, nine)),
                                  np.full((1, 2), 3.0))
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, one)),
                                  np.zeros((1, 2)))


def test_banks_duplicate_slot_write_is_lowest_id_deterministic():
    """Two cohort devices colliding on one slot: the lowest id wins, no
    matter the cohort order XLA scatters in."""
    cohort = jnp.asarray([1, 9], jnp.int32)  # both -> slot 1 of 8
    vals = jnp.asarray([[5.0], [11.0]])
    banks = scatter_cohort(init_banks(8, 8, 1), cohort, vals)
    assert int(banks.owner[0, 1]) == 1
    assert float(banks.deltas[0, 1, 0]) == 5.0


# ---------------------------------------------------------------------------
# sampler / churn / stragglers
# ---------------------------------------------------------------------------


def test_sampler_sorted_deterministic_and_full_cohort_is_arange():
    key = jax.random.PRNGKey(3)
    avail = jnp.ones((50,), bool)
    cohort, member, rank = sample_cohort(key, avail, 8)
    cohort2, _, _ = sample_cohort(key, avail, 8)
    np.testing.assert_array_equal(np.asarray(cohort), np.asarray(cohort2))
    assert np.all(np.diff(np.asarray(cohort)) > 0)  # sorted, no repeats
    assert bool(member.all())
    assert sorted(np.asarray(rank).tolist()) == list(range(8))
    full, _, _ = sample_cohort(key, avail, 50)
    np.testing.assert_array_equal(np.asarray(full), np.arange(50))


def test_sampler_respects_availability():
    avail = jnp.zeros((40,), bool).at[10:20].set(True)
    for s in range(5):
        cohort, member, _ = sample_cohort(jax.random.PRNGKey(s), avail, 5)
        assert bool(member.all())
        assert np.all((np.asarray(cohort) >= 10) & (np.asarray(cohort) < 20))
    # fewer available than K: the filler rows are flagged out
    cohort, member, _ = sample_cohort(jax.random.PRNGKey(0), avail, 15)
    assert int(member.sum()) == 10
    assert np.all(np.asarray(cohort)[np.asarray(member)] >= 10)


def test_churn_window_and_rate():
    key = jax.random.PRNGKey(0)
    arrival, departure = churn.init_arrival_departure(
        key, 200, steps=100, arrival_spread=0.5, mean_lifetime=20.0)
    arr, dep = np.asarray(arrival), np.asarray(departure)
    assert arr.min() >= 0 and arr.max() < 50  # spread over half the run
    assert np.all(dep > arr)  # min lifetime 1 round
    a0 = churn.availability(arrival, departure, 0, key, 1.0)
    np.testing.assert_array_equal(np.asarray(a0), arr <= 0)
    late = churn.availability(arrival, departure, 10**6, key, 1.0)
    assert not bool(late.any())  # everyone has departed
    none = churn.availability(arrival, departure, 0, key, 0.0)
    assert not bool(none.any())
    # defaults: immortal, always up
    arrival, departure = churn.init_arrival_departure(key, 50, steps=100)
    assert bool(churn.availability(arrival, departure, 99, key, 1.0).all())


def test_straggler_deadline_and_defaults():
    key = jax.random.PRNGKey(1)
    assert np.all(np.asarray(stragglers.init_speed(key, 10, 0.0)) == 1.0)
    speed = stragglers.init_speed(key, 1000, 1.0)
    lat = stragglers.latencies(key, speed)
    assert bool(stragglers.deadline_mask(lat, float("inf")).all())
    frac = float(stragglers.deadline_mask(lat, 0.5).mean())
    assert 0.0 < frac < 1.0  # a finite deadline drops a real fraction


def test_straggler_deadline_shrinks_cohort_in_engine(data):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M, speed_sigma=0.5,
                          straggler_deadline=0.3)
    eng = run_population(pdata, xte, yte, cfg, pop, steps=STEPS,
                         eval_every=2)
    fracs = [m["cohort_frac"] for m in eng.metrics]
    assert min(fracs) < 1.0


# ---------------------------------------------------------------------------
# hierarchy
# ---------------------------------------------------------------------------


def test_site_mac_sum_noiseless_equals_flat_sum():
    key = jax.random.PRNGKey(5)
    frames = jax.random.normal(key, (12, 30))
    sites = jnp.asarray(np.arange(12) % 3, jnp.int32)
    y = site_mac_sum(frames, sites, 3, key, 0.0, backhaul_sigma2=0.0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.sum(frames, axis=0)),
                               rtol=1e-6)


def test_site_mac_noise_grows_with_sites():
    key = jax.random.PRNGKey(6)
    frames = jnp.zeros((12, 4000))
    var = {}
    for n_sites in (1, 4):
        sites = jnp.asarray(np.arange(12) % n_sites, jnp.int32)
        y = site_mac_sum(frames, sites, n_sites, key, 1.0)
        var[n_sites] = float(jnp.var(y))
    assert var[4] > 2.5 * var[1]  # ~n_sites-fold effective noise


def test_hierarchical_run_executes_and_differs_from_flat(data):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    pdata = PopulationData.from_dense(xd, yd)
    flat = run_population(pdata, xte, yte, cfg,
                          PopulationConfig(m_total=M, k_cohort=M),
                          steps=STEPS, eval_every=2)
    hier = run_population(pdata, xte, yte, cfg,
                          PopulationConfig(m_total=M, k_cohort=M, n_sites=2),
                          steps=STEPS, eval_every=2)
    assert hier.losses != flat.losses  # extra per-site receiver noise
    assert all(np.isfinite(hier.losses))


# ---------------------------------------------------------------------------
# sweep integration + overrides
# ---------------------------------------------------------------------------


def test_population_sweep_default_point_matches_base_run(data):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd()
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M)
    base = run_population(pdata, xte, yte, cfg, pop, steps=STEPS,
                          eval_every=2)
    res = run_population_sweep(
        pdata, (xte, yte), cfg, pop,
        {"straggler_deadline": [float("inf"), 0.2],
         "avail_rate": [1.0, 0.5]},
        steps=STEPS, eval_every=2)
    default = [r for r in res.records
               if r["straggler_deadline"] == float("inf")
               and r["avail_rate"] == 1.0]
    assert len(default) == 1
    # accs bitwise, losses to the ULP — the vmapped loss reduction can
    # reassociate (the dense sweep tests pin the same contract)
    assert default[0]["accs"] == base.accs
    np.testing.assert_allclose(default[0]["losses"], base.losses, rtol=1e-6)
    # the degraded points genuinely shrink participation
    hit = [r for r in res.records if r["straggler_deadline"] == 0.2]
    assert all(min(m["cohort_frac"] for m in r["metrics"]) < 1.0
               for r in hit)


def test_population_sweep_k_active_axis(data):
    (xd, yd), (xte, yte) = data
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M)
    res = run_population_sweep(pdata, (xte, yte), _adsgd(), pop,
                               {"k_active": [M, M // 2]},
                               steps=STEPS, eval_every=2)
    fracs = {r["k_active"]: r["metrics"][0]["cohort_frac"]
             for r in res.records}
    assert fracs[M] == 1.0
    assert fracs[M // 2] == pytest.approx(0.5)
    with pytest.raises(ValueError, match="k_active"):
        run_population_sweep(pdata, (xte, yte), _adsgd(), pop,
                             {"k_active": [M + 1]}, steps=STEPS)
    with pytest.raises(KeyError, match="m_active"):
        run_population_sweep(pdata, (xte, yte), _adsgd(), pop,
                             {"m_active": [M]}, steps=STEPS)


def test_unknown_population_override_raises(data):
    (xd, yd), (xte, yte) = data
    exp = PopulationExperiment(cfg=_adsgd(),
                               pop=PopulationConfig(m_total=M, k_cohort=M),
                               steps=STEPS)
    cp = CompiledPopulation(PopulationData.from_dense(xd, yd), xte, yte, exp)
    with pytest.raises(AttributeError, match="unknown population override"):
        cp.with_overrides(bank_size=jnp.float32(4))


# ---------------------------------------------------------------------------
# scale: M = 1e5 with banked memory law
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_population_scale_1e5_runs_with_banked_memory():
    """M = 10^5 devices, K = 16 cohort, capacity 2048: the run executes as
    one scan and the persistent d-sized state is ~capacity-sized, nearly
    50x below the dense (M, d) footprint."""
    m_total, k, cap = 100_000, 16, 2048
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=2000, n_test=400, dim=16, n_classes=4, noise=2.0, seed=0)
    part = population_partition(ytr, m=m_total, b=32, kind="iid", seed=0)
    pdata = PopulationData.from_pool(xtr, ytr, part)
    pop = PopulationConfig(m_total=m_total, k_cohort=k, capacity=cap,
                          bank_size=256, avail_rate=0.9, speed_sigma=0.5,
                          straggler_deadline=5.0)
    cfg = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                    total_steps=3, projection="dense", amp_iters=4,
                    mean_removal_steps=1)
    exp = PopulationExperiment(cfg=cfg, pop=pop, steps=3, eval_every=1)
    cp = CompiledPopulation(pdata, xte, yte, exp)
    d = cp.d
    banks = cp.pstate0.banks
    assert banks.deltas.shape == (cap // 256, 256, d)
    assert banks.deltas.nbytes < m_total * d * 4 / 10  # the memory law
    eng = run_population(pdata, xte, yte, cfg, pop, steps=3, eval_every=1)
    assert len(eng.accs) == 3
    assert all(np.isfinite(eng.losses))


# ---------------------------------------------------------------------------
# robustness: banked EF state under dropped devices, faults, site trimming
# ---------------------------------------------------------------------------


def test_masked_out_cohort_devices_keep_banked_state():
    """Stragglers / churn-dropped cohort rows (mask 0) must neither lose
    nor evolve their banked error accumulators — the EF contract for a
    device that never transmitted this round."""
    g = np.load(GOLDEN)
    grads = jnp.asarray(g["grads"])
    m, d = grads.shape
    cfg = PARITY_CASES["a_dsgd_dense"]
    scheme = get_scheme(cfg, d, m)
    ctx = MACContext(m=m, fading=cfg.fading, csi=scheme.csi)
    cohort = jnp.arange(m, dtype=jnp.int32)
    warm = jax.random.normal(jax.random.PRNGKey(8), (m, d))
    banks = scatter_cohort(init_banks(m, m, d), cohort, warm)
    mask = jnp.ones((m,), jnp.float32).at[jnp.asarray([1, 3])].set(0.0)
    _, banks, _ = population_round(scheme, banks, cohort, mask, grads, 0,
                                   jax.random.PRNGKey(11), ctx, m)
    after = np.asarray(gather_cohort(banks, cohort))
    np.testing.assert_array_equal(after[[1, 3]], np.asarray(warm)[[1, 3]])
    assert not np.array_equal(after[0], np.asarray(warm)[0])


def test_population_fault_trace_matches_dense_engine(data):
    """K == M with faults on: the cohort view of the population fault
    trace reproduces the dense robust engine bitwise (same trace, same
    Byzantine set, same banking)."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(robust=True, byzantine_frac=0.3, byz_scale=4.0,
                 fault_rate=0.25, fault_kind="stale")
    pop = PopulationConfig(m_total=M, k_cohort=M, bank_size=3)
    ref = run_compiled(xd, yd, xte, yte, cfg, steps=STEPS, lr=1e-3,
                       eval_every=2)
    eng = run_population(PopulationData.from_dense(xd, yd), xte, yte, cfg,
                         pop, steps=STEPS, lr=1e-3, eval_every=2)
    assert eng.accs == ref.accs
    assert eng.losses == ref.losses
    assert [m["byz_frac"] for m in eng.metrics] == \
        [m["byz_frac"] for m in ref.metrics]


def test_population_checkpoint_resume_bitwise(data, tmp_path):
    """Interrupt a faulted population run mid-scan, resume from the npz:
    the stitched run equals the uninterrupted one entry for entry."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(robust=True, byzantine_frac=0.25, byz_scale=3.0)
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M, bank_size=3,
                          avail_rate=0.9)
    kw = dict(steps=STEPS, lr=1e-3, eval_every=1)
    plain = run_population(pdata, xte, yte, cfg, pop, **kw)
    ckpt = os.path.join(tmp_path, "pop")
    half = run_population(pdata, xte, yte, cfg, pop, **kw,
                          checkpoint_dir=ckpt, checkpoint_every=2,
                          stop_after_step=3)
    assert half is None  # interrupted: partial state on disk, no result
    resumed = run_population(pdata, xte, yte, cfg, pop, **kw,
                             checkpoint_dir=ckpt, checkpoint_every=2,
                             resume=True)
    assert resumed.accs == plain.accs
    assert resumed.losses == plain.losses
    for a, b in zip(resumed.metrics, plain.metrics):
        assert a == b


def test_site_trim_discards_poisoned_site():
    """Backhaul trimming: one site's OTA partial sum is hijacked to a huge
    value; the trimmed combine stays near the honest sum, the plain
    combine is dragged away."""
    key = jax.random.PRNGKey(7)
    frames = jax.random.normal(key, (12, 40))
    sites = jnp.asarray(np.arange(12) % 4, jnp.int32)
    honest = np.asarray(jnp.sum(frames, axis=0))
    bad = jnp.where((sites == 2)[:, None], 1e6, frames)
    plain = np.asarray(site_mac_sum(bad, sites, 4, key, 0.0))
    trimmed = np.asarray(site_mac_sum(bad, sites, 4, key, 0.0,
                                      site_trim_frac=0.25))
    assert np.abs(plain - honest).max() > 1e5
    assert np.abs(trimmed - honest).max() < np.abs(plain - honest).max() / 100


def test_site_trim_hierarchical_run_executes(data):
    (xd, yd), (xte, yte) = data
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M, n_sites=2,
                          site_trim_frac=0.3)
    eng = run_population(pdata, xte, yte, _adsgd(), pop, steps=STEPS,
                         eval_every=2)
    assert all(np.isfinite(eng.losses))
