"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [  # (n_blocks, c, s_block)
    (1, 128, 32),
    (3, 256, 64),
    (4, 512, 128),
    (2, 384, 96),
    (5, 64, 16),
]


@pytest.mark.parametrize("nb,c,sb", SHAPES)
@pytest.mark.parametrize("rademacher", [True, False])
def test_project_forward_matches_oracle(nb, c, sb, rademacher):
    x = jax.random.normal(jax.random.PRNGKey(nb), (nb, c), jnp.float32)
    yk = ops.ota_project(x, seed=11, s_block=sb, rademacher=rademacher,
                         use_kernel=True)
    yr = ops.ota_project(x, seed=11, s_block=sb, rademacher=rademacher,
                         use_kernel=False)
    assert yk.shape == (nb, sb)
    np.testing.assert_allclose(yk, yr, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("nb,c,sb", SHAPES)
@pytest.mark.parametrize("rademacher", [True, False])
def test_project_transpose_matches_oracle(nb, c, sb, rademacher):
    y = jax.random.normal(jax.random.PRNGKey(nb + 7), (nb, sb), jnp.float32)
    tk = ops.ota_project_t(y, seed=11, c=c, rademacher=rademacher,
                           use_kernel=True)
    tr = ops.ota_project_t(y, seed=11, c=c, rademacher=rademacher,
                           use_kernel=False)
    assert tk.shape == (nb, c)
    np.testing.assert_allclose(tk, tr, rtol=3e-5, atol=3e-5)


def test_projection_adjoint():
    """<A x, y> == <x, A^T y> for the generated operator."""
    nb, c, sb = 3, 256, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (nb, c))
    y = jax.random.normal(jax.random.PRNGKey(1), (nb, sb))
    ax = ops.ota_project(x, seed=5, s_block=sb)
    aty = ops.ota_project_t(y, seed=5, c=c)
    np.testing.assert_allclose(float(jnp.vdot(ax, y)),
                               float(jnp.vdot(x, aty)), rtol=1e-4)


@pytest.mark.parametrize("n,tile", [(1024, 256), (4096, 1 << 16), (999, 7)])
def test_ef_sparsify_kernel(n, tile):
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    d = jax.random.normal(jax.random.PRNGKey(1), (n,))
    tau = 0.7
    sk, dk = ops.ef_sparsify(g, d, tau, use_kernel=True)
    sr, dr = ops.ef_sparsify(g, d, tau, use_kernel=False)
    np.testing.assert_allclose(sk, sr)
    np.testing.assert_allclose(dk, dr)
    # EF conservation: g_sp + delta' == g + delta exactly
    np.testing.assert_allclose(sk + dk, g + d, rtol=1e-6, atol=1e-6)


def test_hash_statistics():
    A = ref.block_matrix_ref(0, jnp.uint32(3), 256, 512, rademacher=False)
    assert abs(float(A.mean())) < 5e-3
    np.testing.assert_allclose(float(A.var() * 256), 1.0, rtol=5e-2)
    Ar = ref.block_matrix_ref(0, jnp.uint32(3), 256, 512, rademacher=True)
    assert set(np.unique(np.abs(np.asarray(Ar)))) == {np.float32(1 / 16.0)}


def test_blocks_are_decorrelated():
    a = ref.block_matrix_ref(0, jnp.uint32(1), 64, 128)
    b = ref.block_matrix_ref(0, jnp.uint32(2), 64, 128)
    corr = float(jnp.abs(jnp.vdot(a, b)) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    assert corr < 0.1


@pytest.mark.parametrize("n,tile", [(10007, 1 << 10),   # prime n
                                    (97, 8), (5, 16), (1023, 256)])
def test_ef_sparsify_pads_odd_lengths(n, tile):
    """Prime/odd n must pad up to the tile (ceil(n/tile) programs), not
    degenerate to tile=1 (n programs); outputs sliced back, value-exact."""
    from repro.kernels.ef_sparsify import ef_sparsify_pallas
    g = jax.random.normal(jax.random.PRNGKey(2), (n,))
    d = jax.random.normal(jax.random.PRNGKey(3), (n,))
    tau = jnp.float32(0.5)
    sp, nd = ef_sparsify_pallas(g, d, tau, tile=tile)
    sr, dr = ref.ef_sparsify_ref(g, d, tau)
    assert sp.shape == (n,) and nd.shape == (n,)
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(nd), np.asarray(dr))


def test_ef_sparsify_lazy_interpret_default():
    """interpret=None resolves per call from the live backend (CPU here),
    matching ops.interpret_default — not a hardcoded import-time value."""
    from repro.kernels.ef_sparsify import ef_sparsify_pallas
    g = jax.random.normal(jax.random.PRNGKey(4), (64,))
    d = jnp.zeros((64,))
    sp, nd = ef_sparsify_pallas(g, d, jnp.float32(0.3))   # default None
    sr, dr = ref.ef_sparsify_ref(g, d, jnp.float32(0.3))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(sr))
    assert ops.interpret_default() is True  # CPU test env
