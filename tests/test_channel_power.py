"""Channel frame / power-allocation invariants (paper §II-IV, eq. 6/12/21/45)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra "
    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import channel, power

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 2 ** 31 - 1), st.floats(1.0, 1000.0),
       st.booleans())
def test_frame_power_equals_pt(seed, p_t, use_mr):
    """||x_m||^2 == P_t exactly (paper eq. 12 / 21)."""
    g = jnp.asarray(np.random.default_rng(seed).normal(size=64), jnp.float32)
    frame, alpha = channel.make_frame(g, p_t, use_mr)
    np.testing.assert_allclose(float(channel.frame_power(frame)), p_t,
                               rtol=1e-4)


def test_mean_removal_saves_power():
    """alpha^az >= alpha when the projected gradient has a mean (eq. 19-22)."""
    g = jnp.asarray(np.random.default_rng(0).normal(size=128) + 2.0,
                    jnp.float32)
    _, a_plain = channel.make_frame(g, 100.0, False)
    _, a_mr = channel.make_frame(g, 100.0, True)
    assert float(a_mr) > float(a_plain)


def test_ps_normalize_inverts_noiseless():
    g = jnp.asarray(np.random.default_rng(1).normal(size=64), jnp.float32)
    for use_mr in (False, True):
        frame, alpha = channel.make_frame(g, 37.0, use_mr)
        # noiseless single device: y = frame
        rec = channel.ps_normalize(frame, use_mr)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(g),
                                   rtol=1e-4, atol=1e-5)


def test_mac_superposition():
    frames = jnp.ones((5, 16))
    y = channel.mac_sum(frames, jax.random.PRNGKey(0), sigma2=0.0)
    np.testing.assert_allclose(np.asarray(y), 5.0)
    y2 = channel.mac_sum(frames, jax.random.PRNGKey(0), sigma2=1.0)
    assert float(jnp.var(y2 - y)) > 0.1


@pytest.mark.parametrize("schedule", power.SCHEDULES)
def test_power_schedules_satisfy_average_constraint(schedule):
    """(1/T) sum P_t <= P-bar (paper eq. 6/7)."""
    ps = power.schedule_array(300, 200.0, schedule)
    assert power.verify_average_power(ps, 200.0, tol=1e-3)
    assert (ps > 0).all()


def test_lh_hl_shapes():
    lh = power.schedule_array(300, 200.0, "lh_steps")
    hl = power.schedule_array(300, 200.0, "hl_steps")
    np.testing.assert_allclose(lh[:100], 100.0)
    np.testing.assert_allclose(lh[250:], 300.0)
    np.testing.assert_allclose(hl[:100], 300.0)
    stair = power.schedule_array(300, 200.0, "lh_stair")
    assert stair[0] == pytest.approx(100.0)
    assert stair[-1] == pytest.approx(300.0)
    assert (np.diff(stair) >= -1e-6).all()
