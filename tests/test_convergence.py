"""Numerical checks of the §V convergence machinery (Lemmas 2-4, Thm 1)."""
import math

import pytest

from repro.core import convergence as cv


def test_chi2_quantile_known_values():
    # chi2(df=1): median ~0.4549, 95% ~3.8415
    assert cv.chi2_quantile(1, 0.5) == pytest.approx(0.4549, rel=1e-3)
    assert cv.chi2_quantile(1, 0.95) == pytest.approx(3.8415, rel=1e-3)
    # chi2(df=10): 95% ~18.307
    assert cv.chi2_quantile(10, 0.95) == pytest.approx(18.307, rel=1e-3)


def test_rho_scales_with_dim():
    # ||u|| concentrates near sqrt(d): rho(delta) ~ sqrt(d) for small delta
    r = cv.rho(1e-3, 7850)
    assert math.sqrt(7850) < r < 1.2 * math.sqrt(7850)


def test_lambda_and_sigma_max():
    assert cv.lambda_val(100, 100) == 0.0
    assert cv.lambda_val(100, 0) == 1.0
    assert cv.sigma_max(7850, 3924) == pytest.approx(
        math.sqrt(7850 / 3924) + 1, rel=1e-9)


def test_vt_decreases_with_power_and_m():
    base = dict(d=7850, k=1962, s_tilde=3923, sigma=1.0, g_bound=1.0)
    v_low = cv.v_t(10, m=25, p_t=10.0, **base)
    v_high = cv.v_t(10, m=25, p_t=1000.0, **base)
    assert v_high < v_low
    v_m10 = cv.v_t(10, m=10, p_t=100.0, **base)
    v_m50 = cv.v_t(10, m=50, p_t=100.0, **base)
    assert v_m50 < v_m10        # paper Remark 4: more devices help


def test_sum_v_closed_form_matches_direct():
    kw = dict(d=1000, k=500, s_tilde=499, m=10, sigma=1.0, g_bound=2.0,
              delta_prob=1e-3)
    T = 50
    direct = sum(cv.v_t(t, p_t=200.0, **{k: v for k, v in kw.items()
                                         if k != "delta_prob"},
                        delta_prob=1e-3) for t in range(T))
    closed = cv.sum_v_constant_power(T, p_avg=200.0, **kw)
    assert closed == pytest.approx(direct, rel=1e-6)


def test_theorem1_bound_vanishes_with_T():
    """Pr{E_T} -> 0 as T grows (paper's asymptotic claim after eq. 42)."""
    kw = dict(d=1000, k=900, s_tilde=950, m=25, sigma=0.1, g_bound=1.0)
    c, eps, theta = 1.0, 1.0, 10.0
    bounds = []
    for T in (10_000, 100_000, 1_000_000):
        sv = cv.sum_v_constant_power(T, p_avg=500.0, **kw)
        eta = 0.5 * cv.eta_max(T, c, eps, kw["g_bound"], sv)
        assert eta > 0, "eta ceiling must be positive in this regime"
        b = cv.theorem1_bound(T, eta=eta, c_strong=c, eps=eps,
                              g_bound=kw["g_bound"], sum_v=sv,
                              theta_star_norm=theta)
        bounds.append(b)
    assert bounds[2] < bounds[0]
    assert bounds[2] < 0.05
