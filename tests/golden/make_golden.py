"""Regenerate the fixed-seed parity goldens for tests/test_schemes.py.

Run from the repo root:

    PYTHONPATH=src python tests/golden/make_golden.py

The saved arrays pin the simulated-driver output (ghat, new_deltas) of every
scheme at a fixed seed.  They were first generated from the pre-registry
implementation (``Aggregator.encode`` if/elif chain), so the parity test
proves the ``Scheme`` refactor is bitwise-identical to the seed code.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
from tests.golden.parity_cases import PARITY_CASES  # noqa: E402


def main() -> None:
    from repro.core.schemes import MACContext, get_scheme, round_simulated
    from repro.population import (
        gather_cohort, init_banks, population_round,
    )

    D, M = 256, 6
    base = jax.random.normal(jax.random.PRNGKey(7), (D,))
    grads = base[None, :] + 0.1 * jax.random.normal(jax.random.PRNGKey(4),
                                                    (M, D))
    deltas = jnp.zeros((M, D))
    out = {"grads": np.asarray(grads)}
    for name, cfg in PARITY_CASES.items():
        scheme = get_scheme(cfg, D, M)
        ghat, nd, _ = round_simulated(scheme, grads, deltas, 0,
                                      jax.random.PRNGKey(11))
        out[f"{name}__ghat"] = np.asarray(ghat)
        out[f"{name}__deltas"] = np.asarray(nd)
        print(f"{name:16s} ghat[:3] = {np.asarray(ghat)[:3]}")

    # the sampled-cohort pin: a K == M cohort through the banked population
    # round (bank_size 4 -> 2 banks, exercising the banked addressing) must
    # reproduce a_dsgd_dense bitwise — the equality is asserted separately
    # by tests/test_population.py, like the a_dsgd_csi_err0 pin
    cfg = PARITY_CASES["a_dsgd_dense"]
    scheme = get_scheme(cfg, D, M)
    ctx = MACContext(m=M, fading=cfg.fading, csi=scheme.csi)
    cohort = jnp.arange(M, dtype=jnp.int32)
    ghat, banks, _ = population_round(
        scheme, init_banks(M, 4, D), cohort, jnp.ones((M,), jnp.float32),
        grads, 0, jax.random.PRNGKey(11), ctx, M)
    out["population_full__ghat"] = np.asarray(ghat)
    out["population_full__deltas"] = np.asarray(gather_cohort(banks, cohort))
    print(f"{'population_full':16s} ghat[:3] = {np.asarray(ghat)[:3]}")

    path = os.path.join(os.path.dirname(__file__), "simulated_parity.npz")
    np.savez(path, **out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
