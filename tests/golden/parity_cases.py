"""The fixed-seed scheme configurations pinned by the parity goldens."""
import dataclasses

from repro.configs.base import OTAConfig

PARITY_CASES = {
    "ideal": OTAConfig(scheme="ideal", total_steps=10),
    "a_dsgd_dense": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                              p_avg=500.0, total_steps=10, projection="dense",
                              amp_iters=10, mean_removal_steps=2),
    "a_dsgd_blocked": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                                p_avg=500.0, total_steps=10,
                                projection="blocked", block_size=64,
                                amp_iters=10, mean_removal_steps=2),
    "a_dsgd_rayleigh": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                                 p_avg=500.0, total_steps=10,
                                 projection="dense", amp_iters=10,
                                 mean_removal_steps=2, fading="rayleigh",
                                 fading_threshold=0.9),
    # zero estimation error must reproduce a_dsgd_rayleigh *bitwise* (the
    # equality is asserted separately by test_csi_err_zero_is_fading_golden)
    "a_dsgd_csi_err0": OTAConfig(scheme="a_dsgd_csi_err", csi_err_var=0.0,
                                 s_frac=0.5, k_frac=0.25, p_avg=500.0,
                                 total_steps=10, projection="dense",
                                 amp_iters=10, mean_removal_steps=2,
                                 fading_threshold=0.9),
    "a_dsgd_csi_err": OTAConfig(scheme="a_dsgd_csi_err", csi_err_var=0.25,
                                s_frac=0.5, k_frac=0.25, p_avg=500.0,
                                total_steps=10, projection="dense",
                                amp_iters=10, mean_removal_steps=2,
                                fading_threshold=0.3),
    "a_dsgd_blind": OTAConfig(scheme="a_dsgd_blind", ps_antennas=16,
                              s_frac=0.5, k_frac=0.25, p_avg=500.0,
                              total_steps=10, projection="dense",
                              amp_iters=10, mean_removal_steps=2),
    "a_dsgd_gauss_markov": OTAConfig(scheme="a_dsgd_fading",
                                     fading_process="gauss_markov",
                                     fading_rho=0.95, fading_window=32,
                                     s_frac=0.5, k_frac=0.25, p_avg=500.0,
                                     total_steps=10, projection="dense",
                                     amp_iters=10, mean_removal_steps=2),
    # geometry ON over Rayleigh fading: pins the large-scale gain composition
    # (repro.core.geometry, DESIGN.md §12); geometry OFF cases above are the
    # bitwise no-op reference
    "a_dsgd_geometry": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                                 p_avg=500.0, total_steps=10,
                                 projection="dense", amp_iters=10,
                                 mean_removal_steps=2, fading="rayleigh",
                                 fading_threshold=0.9, geometry="disk",
                                 cell_radius=500.0, path_loss_exp=3.0),
    "d_dsgd": OTAConfig(scheme="d_dsgd", s_frac=0.5, p_avg=500.0,
                        total_steps=10),
    "signsgd": OTAConfig(scheme="signsgd", s_frac=0.5, p_avg=500.0,
                         total_steps=10),
    "qsgd": OTAConfig(scheme="qsgd", s_frac=0.5, p_avg=500.0, total_steps=10),
}


def local_identity(cfg: OTAConfig) -> OTAConfig:
    """``cfg`` with the local-compute axis pinned explicitly at its
    identity point (``local=sgd, local_epochs=1`` — the paper's
    one-SGD-step device, repro.local)."""
    return dataclasses.replace(cfg, local="sgd", local_epochs=1,
                               prox_mu=0.0, dyn_alpha=0.0)


#: every golden case with the local axis pinned at identity — resolved
#: against the SAME golden arrays, so make_golden regenerates nothing:
#: tests/test_local.py asserts each is byte-identical to its base golden
LOCAL_IDENTITY_CASES = {
    name: local_identity(cfg) for name, cfg in PARITY_CASES.items()
}
