"""The fixed-seed scheme configurations pinned by the parity goldens."""
from repro.configs.base import OTAConfig

PARITY_CASES = {
    "ideal": OTAConfig(scheme="ideal", total_steps=10),
    "a_dsgd_dense": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                              p_avg=500.0, total_steps=10, projection="dense",
                              amp_iters=10, mean_removal_steps=2),
    "a_dsgd_blocked": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                                p_avg=500.0, total_steps=10,
                                projection="blocked", block_size=64,
                                amp_iters=10, mean_removal_steps=2),
    "a_dsgd_rayleigh": OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25,
                                 p_avg=500.0, total_steps=10,
                                 projection="dense", amp_iters=10,
                                 mean_removal_steps=2, fading="rayleigh",
                                 fading_threshold=0.9),
    "d_dsgd": OTAConfig(scheme="d_dsgd", s_frac=0.5, p_avg=500.0,
                        total_steps=10),
    "signsgd": OTAConfig(scheme="signsgd", s_frac=0.5, p_avg=500.0,
                         total_steps=10),
    "qsgd": OTAConfig(scheme="qsgd", s_frac=0.5, p_avg=500.0, total_steps=10),
}
