"""Optimizer math, data-pipeline properties, checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import TokenStream, federated_split, make_classification
from repro.optim.optim import Optimizer
from repro.train.checkpoint import load_checkpoint, save_checkpoint


def test_adam_matches_reference():
    opt = Optimizer(name="adam", lr=0.1)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5, -0.1])}
    p1, s1 = opt.apply(params, g, state)
    # reference numpy adam, step 1
    m = 0.1 * np.asarray([0.5, -0.1])
    v = 0.001 * np.asarray([0.25, 0.01])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    ref = np.asarray([1.0, -2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), ref, rtol=1e-5)


def test_warmup_cosine_schedule():
    opt = Optimizer(name="adam", lr=1.0, warmup_steps=10, total_steps=110)
    assert float(opt.lr_at(0)) == 0.0
    assert float(opt.lr_at(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(opt.lr_at(110)) == pytest.approx(0.0, abs=1e-6)
    assert float(opt.lr_at(60)) == pytest.approx(0.5, rel=1e-2)


def test_sgd_and_momentum():
    for name in ("sgd", "momentum"):
        opt = Optimizer(name=name, lr=0.5)
        params = {"w": jnp.ones(3)}
        state = opt.init(params)
        g = {"w": jnp.ones(3)}
        p1, s1 = opt.apply(params, g, state)
        assert float(p1["w"][0]) == pytest.approx(0.5)


def test_grad_clip():
    opt = Optimizer(name="sgd", lr=1.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    p1, _ = opt.apply(params, {"w": jnp.full(4, 10.0)}, opt.init(params))
    assert float(jnp.linalg.norm(p1["w"])) == pytest.approx(1.0, rel=1e-4)


def test_token_stream_deterministic_and_sharded():
    ts = TokenStream(vocab=128, seq_len=32, batch=8, seed=1)
    b1 = ts.batch_at(5, shard=0, n_shards=2)
    b2 = ts.batch_at(5, shard=0, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ts.batch_at(5, shard=1, n_shards=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["tokens"].max() < 128


def test_federated_split_iid_and_noniid():
    (x, y), _ = make_classification(n_train=4000, n_test=10)
    xd, yd = federated_split(x, y, m=8, b=100, iid=True, seed=0)
    assert xd.shape == (8, 100, 784)
    # IID: most devices see most classes
    assert np.mean([len(np.unique(yy)) for yy in yd]) > 6
    xn, yn = federated_split(x, y, m=8, b=100, iid=False, seed=0)
    # non-IID (paper §VI): each device has exactly <= 2 classes
    assert all(len(np.unique(yy)) <= 2 for yy in yn)


def test_classification_surrogate_learnable():
    (x, y), (xt, yt) = make_classification(n_train=2000, n_test=500, seed=0)
    # linear probe via least squares one-vs-all should beat chance easily
    Y = np.eye(10)[y]
    w, *_ = np.linalg.lstsq(x, Y, rcond=None)
    acc = (xt @ w).argmax(1) == yt
    assert acc.mean() > 0.5


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": {"w": jnp.ones((2, 3))},
                     "count": jnp.asarray(7, jnp.int32)},
             "stack": (jnp.zeros(2), jnp.ones(3))}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, state, step=42)
    loaded, step = load_checkpoint(path)
    assert step == 42
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), state, loaded)


def test_checkpoint_roundtrips_bfloat16_and_complex(tmp_path):
    """Extended dtypes survive: bfloat16 rides a uint bit-pattern view
    (npz would degrade it to an opaque void record), complex is native."""
    state = {"bf": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16),
             "half": jnp.asarray([[0.5, 1.0]], jnp.float16),
             "cx": jnp.asarray([1 + 2j, -3.5j], jnp.complex64),
             "nested": {"bf": jnp.ones((2, 2), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "dtypes.npz")
    save_checkpoint(path, state, step=3)
    loaded, step = load_checkpoint(path)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrips_empty_containers(tmp_path):
    """{} and () produce no leaves; sentinel entries keep the structure."""
    state = {"params": {"w": jnp.ones(2)}, "extras": (), "aux": {},
             "mixed": ({"inner": ()}, jnp.zeros(1))}
    path = os.path.join(tmp_path, "empty.npz")
    save_checkpoint(path, state, step=0)
    loaded, _ = load_checkpoint(path)
    assert jax.tree.structure(state) == jax.tree.structure(loaded)
    assert loaded["extras"] == () and loaded["aux"] == {}
    assert loaded["mixed"][0] == {"inner": ()}


def test_checkpoint_step_default_without_meta(tmp_path):
    """Files written without the meta block still load, with step == 0."""
    path = os.path.join(tmp_path, "legacy.npz")
    np.savez(path, **{"state/w": np.arange(3.0)})
    loaded, step = load_checkpoint(path)
    assert step == 0
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.arange(3.0))
    # and the modern writer always returns the exact int it saved
    save_checkpoint(path, {"w": jnp.ones(1)}, step=2**31)
    _, step = load_checkpoint(path)
    assert step == 2**31
