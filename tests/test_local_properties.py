"""Property tests for the local-compute algorithms (repro.local).

Algebraic identities that hold for *any* gradient field, checked through
the real ``local_device_grads`` scan on a quadratic surrogate problem
(the driver is model-agnostic: it takes ``grad_fn(w, x, y)``):

* ``fedprox(mu=0)`` is ``fedavg`` **exactly** (the proximal term is
  ``g + 0 * (w - w0)``, which IEEE-754 addition leaves bit-identical for
  finite g);
* the FedProx delta shrinks monotonically in ``mu`` on quadratic
  objectives in the contractive regime ``lr * (a + mu) < 1``;
* the FedDyn dual telescopes: zero gradients leave the dual and the
  transmitted delta exactly zero for any (E, alpha);
* the masked scan compiled for ``max_epochs = E_max`` but traced at
  ``E <= E_max`` equals the exact-length compile bitwise — the property
  that lets a swept ``local_epochs`` grid share one program.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import OTAConfig  # noqa: E402
from repro.local import get_local, local_device_grads  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

M, D = 3, 8


def _run(algo, *, epochs, max_epochs=None, mu=0.0, alpha=0.0, lr=0.1,
         a=1.0, w0=None, duals=None):
    """Drive local_device_grads on the quadratic field grad = a*(w - c)."""
    cfg = OTAConfig(local=algo, local_epochs=max_epochs or epochs,
                    prox_mu=mu, dyn_alpha=alpha)
    lw = get_local(cfg, local_lr=lr)
    if max_epochs is not None:
        lw = lw.with_overrides(local_epochs=jnp.float32(epochs))
    if w0 is None:
        w0 = jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)
    params = {"w": w0}
    xd = jnp.full((M, D), jnp.float32(a))           # curvature a per coord
    yd = jnp.stack([jnp.full((D,), jnp.float32(i - 1)) for i in range(M)])

    def gf(w, xm, ym):
        return xm * (w - ym)

    if duals is None and lw.has_dual:
        duals = lw.init_dual(M, D)
    momenta = jnp.zeros((M, D), jnp.float32)
    return local_device_grads(lw, gf, params, xd, yd, momenta, duals)


@given(epochs=st.integers(1, 5),
       lr=st.floats(0.01, 0.2),
       a=st.floats(0.0, 2.0))
def test_fedprox_mu0_is_fedavg_exactly(epochs, lr, a):
    d0, _, _ = _run("fedprox", epochs=epochs, mu=0.0, lr=lr, a=a)
    d1, _, _ = _run("fedavg", epochs=epochs, lr=lr, a=a)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@given(epochs=st.integers(1, 6),
       lr=st.floats(0.01, 0.2),
       a=st.floats(0.0, 2.0),
       mus=st.lists(st.floats(0.0, 2.0), min_size=2, max_size=4))
def test_fedprox_delta_norm_monotone_in_mu(epochs, lr, a, mus):
    """In the contractive regime lr*(a + mu) < 1 the quadratic recursion
    gives per-coordinate |delta| = |c| * |S|/E with S = sum of a geometric
    sequence decreasing in mu — so larger mu never grows the delta."""
    norms = []
    for mu in sorted(mus):
        d, _, _ = _run("fedprox", epochs=epochs, mu=mu, lr=lr, a=a)
        norms.append(float(jnp.linalg.norm(d)))
    for hi, lo in zip(norms, norms[1:]):
        assert lo <= hi * (1 + 1e-6)


@given(epochs=st.integers(1, 5),
       alpha=st.floats(0.0, 1.0),
       lr=st.floats(0.01, 0.5))
def test_feddyn_dual_telescopes_to_zero_on_zero_grads(epochs, alpha, lr):
    """grad == 0 everywhere: the inner update is -dual-driven only, and
    with dual(0) = 0 nothing ever moves — delta and dual stay exactly 0."""
    deltas, _, duals = _run("feddyn", epochs=epochs, alpha=alpha, lr=lr,
                            a=0.0, w0=jnp.zeros((D,), jnp.float32))
    # a = 0 makes grad = 0; w0 = c irrelevant since a multiplies it
    np.testing.assert_array_equal(np.asarray(deltas), np.zeros((M, D)))
    np.testing.assert_array_equal(np.asarray(duals), np.zeros((M, D)))


@given(epochs=st.integers(1, 4),
       extra=st.integers(0, 3),
       algo=st.sampled_from(["fedavg", "fedprox", "feddyn"]),
       mu=st.floats(0.0, 1.0))
def test_masked_scan_equals_exact_length_bitwise(epochs, extra, algo, mu):
    """Compiling for max_epochs = E + extra and tracing E epochs equals
    the exact-length compile bit-for-bit (dead epochs leave the carry
    untouched) — the swept-grid bitwise guarantee."""
    exact = _run(algo, epochs=epochs, mu=mu, alpha=mu)
    padded = _run(algo, epochs=epochs, max_epochs=epochs + extra,
                  mu=mu, alpha=mu)
    for e, p in zip(exact, padded):
        if e is None:
            assert p is None
        else:
            np.testing.assert_array_equal(np.asarray(e), np.asarray(p))


@given(alpha=st.floats(0.05, 1.0), epochs=st.integers(1, 4))
def test_feddyn_dual_update_matches_telescoped_sum(alpha, epochs):
    """dual' - dual == -alpha * (w_E - w_0): the dual is exactly the
    running sum of the linearised corrections, never an approximation."""
    cfg = OTAConfig(local="feddyn", local_epochs=epochs, dyn_alpha=alpha)
    lw = get_local(cfg, local_lr=0.1)
    w0 = jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)
    xd = jnp.ones((M, D), jnp.float32)
    yd = jnp.zeros((M, D), jnp.float32)

    def gf(w, xm, ym):
        return xm * (w - ym)

    duals0 = jnp.full((M, D), 0.25, jnp.float32)
    deltas, _, duals1 = local_device_grads(
        lw, gf, {"w": w0}, xd, yd, jnp.zeros((M, D), jnp.float32), duals0)
    # recover w_E from the transmitted delta: delta = (w0 - wE)/(lr * E)
    w_end = w0[None, :] - deltas * (0.1 * epochs)
    np.testing.assert_allclose(np.asarray(duals1 - duals0),
                               np.asarray(-alpha * (w_end - w0[None, :])),
                               rtol=1e-5, atol=1e-6)


def test_identity_point_rejects_override_of_static_knob():
    """max_epochs is static: with_overrides only accepts the traced
    knobs, so a sweep cannot silently change the compiled scan length."""
    lw = get_local(OTAConfig(local="fedavg", local_epochs=2))
    with pytest.raises(AttributeError):
        lw.with_overrides(max_epochs=4)


@given(epochs=st.integers(2, 5), mu=st.floats(0.0, 1.0))
def test_fedprox_reduces_client_drift_on_heterogeneous_quadratics(
        epochs, mu):
    """The motivating property: devices pulled toward different optima
    drift less (smaller spread of w_E across devices) with mu > 0."""
    def spread(mu_):
        d, _, _ = _run("fedprox", epochs=epochs, mu=mu_, lr=0.1, a=1.0)
        w_end = -np.asarray(d) * (0.1 * epochs)  # w_E - w0 per device
        return float(np.linalg.norm(w_end - w_end.mean(0, keepdims=True)))
    assert spread(mu) <= spread(0.0) * (1 + 1e-6)
