"""Local-compute axis (repro.local): parity pins, algorithms, faults, sweeps.

Acceptance bars (docs/DESIGN.md §11):

* **identity is bitwise** — ``local=sgd, local_epochs=1`` (the default)
  reproduces every committed golden byte-for-byte, at the round level and
  through full dense/population engine runs, analog and digital;
* **one trace fits all** — the multi-epoch scan at a traced E below the
  static bound equals the exact-length loop bitwise (what lets whole
  (E, mu, alpha) grids ride one vmapped program), and the compiled engines
  match the looped reference for every algorithm;
* **duals are honest state** — FedDyn's per-device dual lives in the scan
  carry (dense) / a ``BankedState`` (population), never sees the MAC, and
  keeps its semantics under stale/dropout/Byzantine fault injection.
"""
import dataclasses
import os
import sys

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core.schemes import MACContext, get_scheme, round_simulated
from repro.data.synthetic import federated_split, make_classification
from repro.experiments import run_compiled, run_sweep
from repro.experiments.engine import (
    CompiledExperiment, Experiment, round_keys,
)
from repro.experiments.sweep import LOCAL_VMAP_AXES
from repro.local import (
    LOCAL_REGISTRY, LocalWork, get_local, local_device_grads,
)
from repro.population import (
    PopulationConfig, PopulationData, gather_cohort, init_banks,
    population_round, run_population,
)
from repro.population.engine import CompiledPopulation, PopulationExperiment
from repro.train.paper_repro import (
    device_grads, flat_grad_fn, init_linear, run_federated,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from tests.golden.parity_cases import (  # noqa: E402
    LOCAL_IDENTITY_CASES, PARITY_CASES, local_identity,
)

GOLDEN = np.load(os.path.join(os.path.dirname(__file__), "golden",
                              "simulated_parity.npz"))
STEPS, M, B = 6, 4, 64


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=800, n_test=300, dim=48, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=B, iid=True, seed=0)
    return (xd, yd), (xte, yte)


def _adsgd(**kw):
    base = dict(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                total_steps=STEPS, projection="dense", amp_iters=6,
                mean_removal_steps=2)
    base.update(kw)
    return OTAConfig(**base)


def _final_carry(data, cfg, steps=STEPS, **exp_kw):
    """Run the dense engine and return the raw final scan carry."""
    (xd, yd), (xte, yte) = data
    exp = Experiment(cfg=cfg, steps=steps, eval_every=2, **exp_kw)
    ce = CompiledExperiment(xd, yd, xte, yte, exp)
    keys = round_keys(steps)
    carry, _ = jax.jit(
        lambda c, k: ce.run_segment({}, k, None, c, 0))(ce._carry0(), keys)
    return ce, carry


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------


def test_registry_has_all_four_algorithms():
    assert set(LOCAL_REGISTRY) == {"sgd", "fedavg", "fedprox", "feddyn"}
    for name in LOCAL_REGISTRY:
        lw = get_local(OTAConfig(local=name))
        assert lw.name == name
        assert isinstance(lw, LocalWork)


def test_unknown_local_algorithm_raises():
    with pytest.raises(KeyError, match="unknown local algorithm"):
        get_local(OTAConfig(local="gossip"))


def test_identity_gate_is_sgd_e1_only():
    assert get_local(OTAConfig()).identity
    assert not get_local(OTAConfig(local_epochs=2)).identity
    for name in ("fedavg", "fedprox", "feddyn"):
        assert not get_local(OTAConfig(local=name)).identity


def test_with_overrides_rejects_unknown_attrs():
    lw = get_local(OTAConfig(local="feddyn"))
    with pytest.raises(AttributeError, match="unknown local override"):
        lw.with_overrides(byz_scale=jnp.float32(1.0))


def test_legacy_local_steps_conflicts_with_local_axis(data):
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(local="fedavg", local_epochs=2)
    with pytest.raises(ValueError, match="local_steps"):
        run_compiled(xd, yd, xte, yte, cfg, STEPS, local_steps=3)
    with pytest.raises(ValueError, match="local_steps"):
        run_federated(xd, yd, xte, yte, cfg, STEPS, local_steps=3)


# ---------------------------------------------------------------------------
# golden parity: the identity point is bitwise the committed goldens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", sorted(LOCAL_IDENTITY_CASES))
def test_local_pinned_round_matches_golden(case):
    """Explicitly pinning local=sgd/E=1 changes no scheme numerics: every
    committed golden is reproduced byte-for-byte (make_golden untouched)."""
    cfg = LOCAL_IDENTITY_CASES[case]
    grads = jnp.asarray(GOLDEN["grads"])
    m, d = grads.shape
    scheme = get_scheme(cfg, d, m)
    ghat, nd, _ = round_simulated(scheme, grads, jnp.zeros((m, d)), 0,
                                  jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(ghat), GOLDEN[f"{case}__ghat"])
    np.testing.assert_array_equal(np.asarray(nd), GOLDEN[f"{case}__deltas"])


def test_local_pinned_population_round_matches_golden():
    """The banked population round under the pinned config reproduces the
    population_full golden byte-for-byte."""
    cfg = local_identity(PARITY_CASES["a_dsgd_dense"])
    grads = jnp.asarray(GOLDEN["grads"])
    m, d = grads.shape
    scheme = get_scheme(cfg, d, m)
    ctx = MACContext(m=m, fading=cfg.fading, csi=scheme.csi)
    cohort = jnp.arange(m, dtype=jnp.int32)
    ghat, banks, _ = population_round(
        scheme, init_banks(m, 4, d), cohort, jnp.ones((m,), jnp.float32),
        grads, 0, jax.random.PRNGKey(11), ctx, m)
    np.testing.assert_array_equal(np.asarray(ghat),
                                  GOLDEN["population_full__ghat"])
    np.testing.assert_array_equal(np.asarray(gather_cohort(banks, cohort)),
                                  GOLDEN["population_full__deltas"])


@pytest.mark.parametrize("scheme", ["a_dsgd", "d_dsgd"])
def test_run_compiled_identity_pin_bitwise(data, scheme):
    """Full dense runs: default config == explicitly pinned local axis,
    bitwise, analog and digital."""
    (xd, yd), (xte, yte) = data
    base = _adsgd(scheme=scheme)
    r0 = run_compiled(xd, yd, xte, yte, base, STEPS, eval_every=2)
    r1 = run_compiled(xd, yd, xte, yte, local_identity(base), STEPS,
                      eval_every=2)
    np.testing.assert_array_equal(r0.all_accs, r1.all_accs)
    np.testing.assert_array_equal(r0.all_losses, r1.all_losses)


@pytest.mark.parametrize("scheme", ["a_dsgd", "d_dsgd"])
def test_run_population_identity_pin_bitwise(data, scheme):
    """Full population runs: default == pinned local axis, bitwise."""
    (xd, yd), (xte, yte) = data
    base = _adsgd(scheme=scheme)
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M)
    r0 = run_population(pdata, xte, yte, base, pop, STEPS, eval_every=2)
    r1 = run_population(pdata, xte, yte, local_identity(base), pop, STEPS,
                        eval_every=2)
    np.testing.assert_array_equal(r0.all_accs, r1.all_accs)
    np.testing.assert_array_equal(r0.all_losses, r1.all_losses)


def test_scan_path_at_e1_matches_device_grads_bitwise(data):
    """The masked-epoch scan, compiled for max_epochs=2 but traced at E=1,
    produces the legacy single gradient bit-for-bit — the property that
    makes a swept local_epochs grid bitwise per-point."""
    (xd, yd), _ = data
    xd, yd = jnp.asarray(xd), jnp.asarray(yd)
    params = init_linear(xd.shape[-1], int(np.max(yd)) + 1,
                         jax.random.PRNGKey(0))
    _, unravel = jax.flatten_util.ravel_pytree(params)
    lw = get_local(OTAConfig(local="sgd", local_epochs=2))
    assert not lw.identity and lw.max_epochs == 2
    lw = lw.with_overrides(local_epochs=jnp.float32(1.0))
    d = jax.flatten_util.ravel_pytree(params)[0].shape[0]
    zeros = jnp.zeros((M, d), jnp.float32)
    got, _, _ = local_device_grads(lw, flat_grad_fn(unravel), params,
                                   xd, yd, zeros)
    want, _ = device_grads(params, unravel, xd, yd, zeros)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# algorithms: compiled == looped, and every scheme composes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo,kw", [
    ("sgd", {}), ("fedavg", {}),
    ("fedprox", {"prox_mu": 0.3}), ("feddyn", {"dyn_alpha": 0.2}),
])
def test_compiled_matches_looped_reference(data, algo, kw):
    """run_compiled == run_federated entry-for-entry with local work on
    (the engines share local_device_grads, like device_grads before)."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(local=algo, local_epochs=3, **kw)
    rc = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    rl = run_federated(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    np.testing.assert_array_equal(np.asarray(rc.accs), np.asarray(rl.accs))
    np.testing.assert_array_equal(np.asarray(rc.losses),
                                  np.asarray(rl.losses))


@pytest.mark.parametrize("scheme", ["ideal", "d_dsgd", "signsgd", "qsgd"])
def test_every_mac_scheme_composes_with_feddyn(data, scheme):
    """The scheme encode/decode contract is untouched: the dual-carrying
    algorithm runs through analog, digital, and baseline transports."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(scheme=scheme, local="feddyn", local_epochs=2,
                 dyn_alpha=0.1)
    r = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    assert np.all(np.isfinite(r.all_losses))


def test_feddyn_dual_evolves_in_dense_carry(data):
    """The dense carry gains a (M, d) dual element that actually moves."""
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.3)
    ce, carry = _final_carry(data, cfg)
    assert ce.localwork.has_dual
    duals = np.asarray(carry[4])
    assert duals.shape == (M, ce.d)
    assert np.all(np.isfinite(duals)) and np.any(duals != 0.0)


def test_population_feddyn_full_cohort_matches_dense(data):
    """K == M population FedDyn == dense FedDyn bitwise: banked duals and
    the scan-carried duals are the same state under the same RNG layout."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.3)
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M)
    rp = run_population(pdata, xte, yte, cfg, pop, STEPS, eval_every=2)
    rd = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    np.testing.assert_array_equal(rp.all_losses, rd.all_losses)
    np.testing.assert_array_equal(rp.all_accs, rd.all_accs)


def test_population_feddyn_banks_duals_with_eviction():
    """capacity < M: dual slots evict direct-mapped; a cold read is dual=0
    — FedDyn's fresh-device init — so the run stays finite and banked."""
    from repro.data.partition import population_partition
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=1200, n_test=300, dim=16, n_classes=4, noise=2.0, seed=0)
    m_total, k, cap = 64, 8, 16
    part = population_partition(ytr, m=m_total, b=16, kind="iid", seed=0)
    pdata = PopulationData.from_pool(xtr, ytr, part)
    pop = PopulationConfig(m_total=m_total, k_cohort=k, capacity=cap,
                           bank_size=8)
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.2)
    exp = PopulationExperiment(cfg=cfg, pop=pop, steps=STEPS, eval_every=2)
    cp = CompiledPopulation(pdata, xte, yte, exp)
    assert cp.dual_banks0 is not None
    assert cp.dual_banks0.deltas.shape == (cap // 8, 8, cp.d)
    keys = round_keys(STEPS)
    carry, outs = jax.jit(
        lambda c, k: cp.run_segment({}, k, None, c, 0))(cp._carry0(), keys)
    dual_banks = carry[3]
    assert np.all(np.isfinite(np.asarray(dual_banks.deltas)))
    assert np.any(np.asarray(dual_banks.owner) >= 0)
    assert np.all(np.isfinite(np.asarray(outs["loss"])))


# ---------------------------------------------------------------------------
# fault interaction: duals never see the MAC
# ---------------------------------------------------------------------------


def test_zero_rate_robust_noop_with_local_work(data):
    """robust=True + zero rates is still a bitwise no-op with multi-epoch
    FedDyn enabled (the fault path transforms transmitted deltas only)."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.2)
    r0 = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    r1 = run_compiled(xd, yd, xte, yte,
                      dataclasses.replace(cfg, robust=True), STEPS,
                      eval_every=2)
    np.testing.assert_array_equal(r0.all_losses, r1.all_losses)
    np.testing.assert_array_equal(r0.all_accs, r1.all_accs)


@pytest.mark.parametrize("fault_kw", [
    {"fault_rate": 0.5, "fault_kind": "stale"},
    {"fault_rate": 0.5, "fault_kind": "dropout"},
    {"byzantine_frac": 0.25},
])
def test_feddyn_first_round_duals_ignore_faults(data, fault_kw):
    """Faults transform the *transmitted* frame/gradient after local
    compute, so the round-1 dual update is identical with faults on
    (after round 1 the global model diverges, so compare one round)."""
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.3)
    _, clean = _final_carry(data, cfg, steps=1)
    _, faulted = _final_carry(
        data, dataclasses.replace(cfg, robust=True, **fault_kw), steps=1)
    np.testing.assert_array_equal(np.asarray(clean[4]),
                                  np.asarray(faulted[4]))


def test_feddyn_duals_stay_finite_under_sustained_faults(data):
    """Stale + Byzantine at high rates for the whole run: the banked dual
    state never sees a non-finite value (no NaN leak into duals)."""
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.3,
                 robust=True, byzantine_frac=0.25, byz_scale=20.0,
                 fault_rate=0.4, fault_kind="stale")
    _, carry = _final_carry(data, cfg)
    duals = np.asarray(carry[4])
    assert np.all(np.isfinite(duals))


def test_checkpoint_resume_feddyn_bitwise(data, tmp_path):
    """The dual rides the checkpointed carry: interrupt + resume == the
    uninterrupted run, bitwise."""
    (xd, yd), (xte, yte) = data
    cfg = _adsgd(local="feddyn", local_epochs=2, dyn_alpha=0.2)
    full = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
    ck = dict(checkpoint_dir=str(tmp_path), checkpoint_every=2)
    assert run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2,
                        stop_after_step=2, **ck) is None
    resumed = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2,
                           resume=True, **ck)
    np.testing.assert_array_equal(full.all_losses, resumed.all_losses)
    np.testing.assert_array_equal(full.all_accs, resumed.all_accs)


# ---------------------------------------------------------------------------
# sweeps: the new vmapped axes
# ---------------------------------------------------------------------------


def test_local_axes_are_registered_vmapped():
    assert LOCAL_VMAP_AXES == ("local_epochs", "prox_mu", "dyn_alpha")


@pytest.mark.slow
def test_sweep_local_axes_vmapped_match_looped(data):
    """A (local_epochs, prox_mu) grid on one vmapped program matches
    per-point compiled runs (accs exactly, per the sweep convention —
    losses to float32 ulp, as vmapping may reassociate reductions),
    including the E=1 point, which equals the legacy identity run."""
    (xd, yd), (xte, yte) = data
    base = _adsgd(local="fedprox")
    res = run_sweep((xd, yd), (xte, yte), base,
                    {"local_epochs": [1, 3], "prox_mu": [0.0, 0.4]},
                    steps=STEPS, eval_every=2)
    assert len(res.records) == 4
    for rec in res.records:
        cfg = dataclasses.replace(base,
                                  local_epochs=int(rec["local_epochs"]),
                                  prox_mu=rec["prox_mu"])
        r = run_compiled(xd, yd, xte, yte, cfg, STEPS, eval_every=2)
        assert rec["accs"] == r.accs
        np.testing.assert_allclose(np.asarray(rec["losses"]),
                                   np.asarray(r.losses), rtol=2e-6)


@pytest.mark.slow
def test_population_sweep_dyn_alpha_vmapped_match_looped(data):
    """dyn_alpha rides the population sweep's vmapped override path."""
    from repro.experiments import run_population_sweep
    (xd, yd), (xte, yte) = data
    base = _adsgd(local="feddyn", local_epochs=2)
    pdata = PopulationData.from_dense(xd, yd)
    pop = PopulationConfig(m_total=M, k_cohort=M)
    res = run_population_sweep(pdata, (xte, yte), base, pop,
                               {"dyn_alpha": [0.0, 0.3]}, steps=STEPS,
                               eval_every=2)
    for rec in res.records:
        cfg = dataclasses.replace(base, dyn_alpha=rec["dyn_alpha"])
        r = run_population(pdata, xte, yte, cfg, pop, STEPS, eval_every=2)
        assert rec["accs"] == r.accs
        np.testing.assert_allclose(np.asarray(rec["losses"]),
                                   np.asarray(r.losses), rtol=2e-6)


def test_sweep_static_local_axis_groups_by_algorithm(data):
    """``local`` itself is a static axis: one compile per algorithm, all
    sharing the vmapped epoch grid."""
    (xd, yd), (xte, yte) = data
    res = run_sweep((xd, yd), (xte, yte), _adsgd(),
                    {"local": ["fedavg", "fedprox"],
                     "local_epochs": [2]}, steps=STEPS, eval_every=2)
    assert len(res.records) == 2
    assert {r["local"] for r in res.records} == {"fedavg", "fedprox"}
