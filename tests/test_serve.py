"""make_serve_step coverage: prefill + decode smoke, cache sharding specs,
the encoder (cross-attention) branch, and the published-params swap.

Single CPU device (conftest pins JAX_PLATFORMS=cpu), so the shardings are
all trivially placeable; what these tests pin is the *contract*: spec trees
match the cache structure, prefill fills the cache the decode steps then
extend, and publish hands decode_fn a tree it actually serves from.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as model_lib
from repro.models import transformer
from repro.train.serve import make_serve_step


def _greedy(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)


def test_serve_step_prefill_and_decode_smoke():
    arch = get_config("smollm_360m").reduced()
    mesh = make_local_mesh()
    B, prompt_len, steps = 2, 4, 3
    serve = make_serve_step(arch, mesh, B, prompt_len + steps,
                            compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32)
    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    params = serve.publish(params)

    # cache sharding tree matches the cache structure, leaves are shardings
    acache = jax.eval_shape(lambda: serve.init_cache(jnp.float32))
    assert (jax.tree.structure(serve.cache_sharding)
            == jax.tree.structure(acache))
    for sh in jax.tree.leaves(serve.cache_sharding):
        assert isinstance(sh, NamedSharding)
    for sh in jax.tree.leaves(serve.param_sharding):
        assert isinstance(sh, NamedSharding)

    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                0, arch.vocab)
    logits, cache = serve.prefill_fn(params, serve.init_cache(jnp.float32),
                                     prompt)
    assert logits.shape == (B, 1, arch.vocab)
    assert bool(jnp.isfinite(logits).all())
    tok = _greedy(logits)
    for i in range(steps):
        logits, cache = serve.decode_fn(params, cache, tok,
                                        jnp.int32(prompt_len + i))
        assert logits.shape == (B, 1, arch.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = _greedy(logits)


def test_serve_prefill_matches_full_forward():
    """Prefill (scan of decode steps) must agree with the full-sequence
    forward at the last position — the cache write path is consistent."""
    arch = get_config("smollm_360m").reduced()
    mesh = make_local_mesh()
    B, L = 2, 6
    serve = make_serve_step(arch, mesh, B, L, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32)
    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                                arch.vocab)
    logits_pre, _ = serve.prefill_fn(params, serve.init_cache(jnp.float32),
                                     prompt)
    logits_full, _, _ = transformer.forward(params, arch, prompt,
                                            compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0, :]),
                               np.asarray(logits_full[:, -1, :]),
                               rtol=2e-4, atol=2e-4)


def test_serve_step_encoder_branch():
    """whisper_base: decode_fn/prefill_fn take an enc_out operand and the
    cache includes cross-attention entries."""
    arch = get_config("whisper_base").reduced()
    assert arch.encoder is not None
    mesh = make_local_mesh()
    B, prompt_len = 2, 3
    serve = make_serve_step(arch, mesh, B, prompt_len + 2,
                            compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32)
    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    frames = 0.02 * jax.random.normal(
        jax.random.PRNGKey(3),
        (B, arch.encoder.n_frames, arch.encoder.d_model))
    enc_out = transformer.encode_audio(params, arch,
                                       frames.astype(jnp.float32))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (B, prompt_len),
                                0, arch.vocab)
    logits, cache = serve.prefill_fn(params, serve.init_cache(jnp.float32),
                                     prompt, enc_out)
    assert bool(jnp.isfinite(logits).all())
    logits, _ = serve.decode_fn(params, cache, _greedy(logits),
                                jnp.int32(prompt_len), enc_out)
    assert logits.shape == (B, 1, arch.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_publish_swap_changes_served_logits():
    """A published-params swap must change what decode_fn serves (and the
    published tree is bitwise the tree that was handed over)."""
    arch = get_config("smollm_360m").reduced()
    mesh = make_local_mesh()
    B = 2
    serve = make_serve_step(arch, mesh, B, 4, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32)
    params_a = model_lib.init_params(arch, jax.random.PRNGKey(0))
    params_b = model_lib.init_params(arch, jax.random.PRNGKey(1))
    ref_b = jax.tree.map(np.asarray, params_b)
    tok = jnp.zeros((B, 1), jnp.int32)

    view_a = serve.publish(params_a)
    logits_a, _ = serve.decode_fn(view_a, serve.init_cache(jnp.float32),
                                  tok, jnp.int32(0))
    view_b = serve.publish(params_b)
    # the served tree is bitwise the published one
    for got, want in zip(jax.tree.leaves(view_b), jax.tree.leaves(ref_b)):
        np.testing.assert_array_equal(np.asarray(got), want)
    logits_b, _ = serve.decode_fn(view_b, serve.init_cache(jnp.float32),
                                  tok, jnp.int32(0))
    assert not np.array_equal(np.asarray(logits_a), np.asarray(logits_b))
