"""Aggregation-scheme behaviour on the simulation driver (paper Alg. 1, §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OTAConfig
from repro.core.schemes import get_scheme, round_simulated

D, M = 512, 10


@pytest.fixture(scope="module")
def grads():
    base = jax.random.normal(jax.random.PRNGKey(7), (D,))
    g = base[None, :] + 0.1 * jax.random.normal(jax.random.PRNGKey(4), (M, D))
    return g


def _cos(a, b):
    return float(jnp.vdot(a, b) /
                 (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12))


def _round(cfg, grads, deltas, step=0, seed=0):
    scheme = get_scheme(cfg, D, M)
    return round_simulated(scheme, grads, deltas, step,
                           jax.random.PRNGKey(seed))


def test_ideal_is_exact_mean(grads):
    ghat, _, _ = _round(OTAConfig(scheme="ideal", total_steps=10), grads,
                        jnp.zeros((M, D)))
    np.testing.assert_allclose(np.asarray(ghat), np.asarray(grads.mean(0)),
                               rtol=1e-5)


@pytest.mark.parametrize("projection", ["dense", "blocked"])
def test_adsgd_estimates_mean(grads, projection):
    cfg = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                    total_steps=10, projection=projection, block_size=128,
                    amp_iters=25, mean_removal_steps=2)
    ghat, new_deltas, met = _round(cfg, grads, jnp.zeros((M, D)))
    assert _cos(ghat, grads.mean(0)) > 0.5
    assert float(met["frame_power"]) == pytest.approx(500.0, rel=1e-3)
    # error accumulators are nonzero (sparsification residual retained)
    assert float(jnp.abs(new_deltas).sum()) > 0


def test_adsgd_error_feedback_reinjects(grads):
    """What is cut at step t is added back at step t+1 (paper eq. 10)."""
    cfg = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=500.0,
                    total_steps=10, projection="dense", amp_iters=10)
    deltas = jnp.zeros((M, D))
    _, deltas1, _ = _round(cfg, grads, deltas)
    # EF conservation per device: g_sp + delta' = g + delta
    g_ec = grads + deltas
    from repro.core.compression import top_k_sparsify
    k = cfg.k_for(D)
    g_sp = jax.vmap(lambda v: top_k_sparsify(v, k))(g_ec)
    np.testing.assert_allclose(np.asarray(g_sp + deltas1),
                               np.asarray(g_ec), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("scheme", ["d_dsgd", "signsgd", "qsgd"])
def test_digital_schemes_positive_alignment(grads, scheme):
    cfg = OTAConfig(scheme=scheme, s_frac=0.5, p_avg=500.0, total_steps=10)
    ghat, _, met = _round(cfg, grads, jnp.zeros((M, D)))
    assert _cos(ghat, grads.mean(0)) > 0.15
    assert int(met["q_t"]) > 0


def test_ddsgd_more_power_better_estimate(grads):
    cos = {}
    for p in (50.0, 5000.0):
        cfg = OTAConfig(scheme="d_dsgd", s_frac=0.5, p_avg=p, total_steps=10)
        ghat, _, _ = _round(cfg, grads, jnp.zeros((M, D)))
        cos[p] = _cos(ghat, grads.mean(0))
    assert cos[5000.0] >= cos[50.0]


def test_adsgd_robust_to_low_power(grads):
    """Paper Fig. 4: A-DSGD degrades little with low P-bar (M superposition)."""
    cos = {}
    for p in (1.0, 500.0):
        cfg = OTAConfig(scheme="a_dsgd", s_frac=0.5, k_frac=0.25, p_avg=p,
                        total_steps=10, projection="dense", amp_iters=25,
                        mean_removal_steps=0)
        ghat, _, _ = _round(cfg, grads, jnp.zeros((M, D)))
        cos[p] = _cos(ghat, grads.mean(0))
    # still positively aligned at P-bar = 1 (where D-DSGD sends 0 bits);
    # the paper's claim is over many EF-corrected iterations, a single
    # round only needs useful alignment
    assert cos[1.0] > 0.15
