"""Distributed train-step tests — spawned in subprocesses so the main pytest
process keeps its single CPU device (the 8-device XLA flag must be set
before jax initialises)."""
import os
import subprocess
import sys

import pytest

_COMMON = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import OTAConfig, TrainConfig
from repro.train.trainer import make_train_step
mesh = jax.make_mesh((4, 2), ("data", "model"))
arch = get_config("smollm_360m").reduced()
tc = TrainConfig(optimizer="adam", lr=1e-3, warmup_steps=0, total_steps=50,
                 compute_dtype="float32", remat=True)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      arch.vocab)}
"""


def _run(snippet, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _COMMON + snippet],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_adsgd_distributed_loss_decreases():
    out = _run(r"""
ota = OTAConfig(scheme="a_dsgd", projection="blocked", block_size=512,
                s_frac=0.25, k_frac=0.5, rademacher=True, p_avg=500.0,
                total_steps=50, amp_iters=10, mean_removal_steps=3)
ts = make_train_step(arch, tc, ota, mesh, ota_axes=("data",), donate=False)
params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
jfn = ts.jitted(batch)
losses = []
for step in range(5):
    params, opt_state, delta, met = jfn(params, opt_state, delta, batch,
                                        jnp.asarray(step),
                                        jax.random.PRNGKey(step))
    losses.append(float(met["global_loss"]))
assert losses[-1] < losses[0], losses
assert float(jnp.abs(delta).sum()) > 0    # error feedback engaged
assert abs(float(met["frame_power"]) - 500.0) < 5.0
print("OK", losses)
""")
    assert "OK" in out


@pytest.mark.slow
def test_ideal_distributed_matches_single_device():
    """psum/M inside shard_map == the same model trained on one device."""
    out = _run(r"""
from repro.models import loss_fn, init_params
from repro.optim.optim import Optimizer
ota = OTAConfig(scheme="ideal", total_steps=50)
ts = make_train_step(arch, tc, ota, mesh, ota_axes=("data",), donate=False)
params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
jfn = ts.jitted(batch)
p1, o1, d1, met = jfn(params, opt_state, delta, batch, jnp.asarray(0),
                      jax.random.PRNGKey(0))
# single-device reference
params_ref = init_params(arch, jax.random.PRNGKey(0))
opt = Optimizer(name="adam", lr=1e-3)
s_ref = opt.init(params_ref)
g = jax.grad(lambda p: loss_fn(p, arch, batch, remat=True,
                               compute_dtype=jnp.float32,
                               loss_chunk=2048)[0])(params_ref)
p_ref, _ = opt.apply(params_ref, g, s_ref)
import numpy as np
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p_ref)):
    # accumulation-order differences pass through Adam's rsqrt: ~1e-4 abs
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=5e-4)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_sliced_layout_matches_flat():
    """O1 optimisation: slice-local layout trains like the flat baseline."""
    out = _run(r"""
from repro.train.trainer import make_train_step_sliced
losses = {}
for layout in ("flat", "sliced"):
    ota = OTAConfig(scheme="a_dsgd", projection="blocked", block_size=512,
                    s_frac=0.25, k_frac=0.5, rademacher=True, p_avg=500.0,
                    total_steps=50, amp_iters=10, mean_removal_steps=3,
                    layout=layout)
    mk = make_train_step_sliced if layout == "sliced" else make_train_step
    ts = mk(arch, tc, ota, mesh, ota_axes=("data",), donate=False)
    params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
    jfn = ts.jitted(batch)
    ls = []
    for step in range(4):
        params, opt_state, delta, met = jfn(params, opt_state, delta, batch,
                                            jnp.asarray(step),
                                            jax.random.PRNGKey(step))
        ls.append(float(met["global_loss"]))
    losses[layout] = ls
assert losses["sliced"][-1] < losses["sliced"][0]
# same math, different element order/noise keys: trajectories agree closely
assert abs(losses["sliced"][-1] - losses["flat"][-1]) < 0.02, losses
print("OK", losses)
""")
    assert "OK" in out


@pytest.mark.slow
def test_site_ota_axes_variant():
    """ota_axes=('data',) vs hierarchical num_groups pre-averaging lowers."""
    out = _run(r"""
ota = OTAConfig(scheme="a_dsgd", projection="blocked", block_size=512,
                s_frac=0.25, k_frac=0.5, p_avg=500.0, total_steps=50,
                amp_iters=5, num_groups=2)
ts = make_train_step(arch, tc, ota, mesh, ota_axes=("data",), donate=False)
params, opt_state, delta = ts.init_state(jax.random.PRNGKey(0))
jfn = ts.jitted(batch)
p, o, dl, met = jfn(params, opt_state, delta, batch, jnp.asarray(0),
                    jax.random.PRNGKey(0))
assert ts.m_devices == 2
print("OK", float(met["global_loss"]))
""")
    assert "OK" in out
