"""Fused AMP decode kernel + chunk-batched projection kernels (interpret
mode) vs the jnp oracles, and the one-A-generation-per-decode guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.amp import (amp_blocked_core, amp_decode, amp_decode_blocked,
                            amp_decode_blocked_scan)
from repro.core.projection import BlockedProjector
from repro.kernels import ops, ref


def _block_sparse_signal(d, c, sb):
    xb = []
    for b in range(d // c):
        key = jax.random.PRNGKey(b)
        idx = jax.random.choice(key, c, (sb // 4,), replace=False)
        vals = jax.random.normal(jax.random.fold_in(key, 1), (sb // 4,))
        xb.append(jnp.zeros(c).at[idx].set(vals))
    return jnp.concatenate(xb)


# ---------------------------------------------------------------------------
# chunk-batched projection kernels: exact parity for Rademacher entries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,c,sb", [(1, 128, 32), (3, 256, 64),
                                     (12, 128, 32), (5, 64, 16)])
def test_batched_projection_exact_rademacher(nb, c, sb):
    """±1/sqrt(s) entries: the batched dot_general accumulates in the same
    order as the oracle matvec, so parity is exact, not just allclose."""
    x = jax.random.normal(jax.random.PRNGKey(nb), (nb, c), jnp.float32)
    yk = ops.ota_project(x, seed=11, s_block=sb, rademacher=True,
                         use_kernel=True)
    yr = ops.ota_project(x, seed=11, s_block=sb, rademacher=True,
                         use_kernel=False)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))
    y = jax.random.normal(jax.random.PRNGKey(nb + 7), (nb, sb), jnp.float32)
    tk = ops.ota_project_t(y, seed=11, c=c, rademacher=True, use_kernel=True)
    tr = ops.ota_project_t(y, seed=11, c=c, rademacher=True,
                           use_kernel=False)
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(tr))


def test_projection_kernel_traced_seed():
    """The SMEM seed operand accepts a traced uint32 (shard-folded seeds)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 128), jnp.float32)

    @jax.jit
    def run(x, seed):
        return ops.ota_project(x, seed=seed, s_block=32, rademacher=True,
                               use_kernel=True)

    yk = run(x, ref.splitmix32(jnp.uint32(3)))
    yr = ops.ota_project(x, seed=ref.splitmix32(jnp.uint32(3)), s_block=32,
                         rademacher=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))


def test_projection_kernel_nb_tile_padding():
    """n_blocks not divisible by nb_tile: padded rows are sliced off."""
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 64), jnp.float32)
    yk = ops.ota_project(x, seed=3, s_block=16, rademacher=True,
                         use_kernel=True, nb_tile=4)
    yr = ops.ota_project(x, seed=3, s_block=16, rademacher=True,
                         use_kernel=False)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))


# ---------------------------------------------------------------------------
# fused single-launch AMP decode
# ---------------------------------------------------------------------------


def test_fused_amp_matches_blocked_scan():
    d, c, sb = 4096, 256, 128
    proj = BlockedProjector(d=d, block_size=c, s_block=sb, seed=5,
                            rademacher=True)
    x = _block_sparse_signal(d, c, sb)
    yb = proj.project(x).reshape(proj.n_blocks, sb)
    x_scan = amp_decode_blocked_scan(yb, proj, iters=20)
    xb_fused = amp_blocked_core(yb, proj.seed, c, iters=20, chunk_blocks=4,
                                use_kernel=True)
    np.testing.assert_allclose(np.asarray(proj.from_blocks(xb_fused)),
                               np.asarray(x_scan), rtol=1e-4, atol=1e-5)
    # and both recover the signal
    rel = float(jnp.linalg.norm(x_scan - x) / jnp.linalg.norm(x))
    assert rel < 0.1, rel


def test_fused_amp_id_offset_decodes_subrange():
    """A device decoding a sub-range of blocks with the encoder's global
    block ids (shard_decode) gets the same answer as the full decode."""
    d, c, sb = 2048, 128, 64
    proj = BlockedProjector(d=d, block_size=c, s_block=sb, seed=9,
                            rademacher=True)
    x = _block_sparse_signal(d, c, sb)
    yb = proj.project(x).reshape(proj.n_blocks, sb)
    full = amp_blocked_core(yb, 9, c, iters=10, chunk_blocks=4,
                            use_kernel=True)
    half = proj.n_blocks // 2
    part = amp_blocked_core(yb[half:], 9, c, iters=10, chunk_blocks=4,
                            id_offset=half, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(part), np.asarray(full[half:]))


def test_amp_decode_dispatches_to_fused_kernel(monkeypatch):
    """use_kernel=True on the projector routes amp_decode through the fused
    Pallas kernel (single launch), not the launch-per-op path."""
    d, c, sb = 1024, 128, 64
    x = _block_sparse_signal(d, c, sb)
    calls = {"fused": 0}
    real = ops.amp_decode_fused_pallas

    def spy(*a, **kw):
        calls["fused"] += 1
        return real(*a, **kw)

    # ops binds the kernel entry point at import time — patch ops' name
    monkeypatch.setattr(ops, "amp_decode_fused_pallas", spy)
    proj_k = BlockedProjector(d=d, block_size=c, s_block=sb, seed=2,
                              rademacher=True, use_kernel=True)
    proj_j = BlockedProjector(d=d, block_size=c, s_block=sb, seed=2,
                              rademacher=True, use_kernel=False)
    y = proj_j.project(x)
    # (jit stays on: Pallas interpret mode recurses under disable_jit; the
    # spy counts trace-time entries of the kernel wrapper)
    xk = amp_decode(y, proj_k, iters=8)
    assert calls["fused"] == 1
    xj = amp_decode(y, proj_j, iters=8)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xj),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# the one-generation-per-block guarantee (acceptance criterion)
# ---------------------------------------------------------------------------


def test_amp_generator_invocations(monkeypatch):
    """The chunked decode generates each block's A exactly ONCE per decode;
    launch-per-op decoding regenerates it 2*amp_iters+1 times.

    Counted on the jnp oracle path under disable_jit: every invocation of
    ref.block_matrix_ref generates the A of each block in its (vmapped)
    chunk once, so the chunked scan makes ceil(n_blocks/chunk) invocations
    — one generation per block in total — while the unfused path makes one
    invocation per projection application (adjoint + forward per iteration,
    + the LS debias)."""
    d, c, sb, iters, chunk = 1024, 128, 64, 5, 4
    proj = BlockedProjector(d=d, block_size=c, s_block=sb, seed=4,
                            rademacher=True)
    x = _block_sparse_signal(d, c, sb)
    yb = proj.project(x).reshape(proj.n_blocks, sb)

    calls = {"n": 0}
    real = ref.block_matrix_ref

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(ref, "block_matrix_ref", counting)
    with jax.disable_jit():
        calls["n"] = 0
        x_scan = amp_blocked_core(yb, 4, c, iters=iters, chunk_blocks=chunk)
        n_chunks = -(-proj.n_blocks // chunk)
        assert calls["n"] == n_chunks, (calls["n"], n_chunks)

        calls["n"] = 0
        x_unfused = amp_decode_blocked(yb, proj, iters=iters)
        assert calls["n"] == 2 * iters + 1, calls["n"]

    # allclose parity between the fused structure and the unfused path
    np.testing.assert_allclose(np.asarray(proj.from_blocks(x_scan)),
                               np.asarray(x_unfused), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: the sharded slice driver honours use_kernel
# ---------------------------------------------------------------------------


def test_sharded_round_kernel_path_matches_jnp():
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import OTAConfig
    from repro.core import distributed
    from repro.core.schemes import MACContext, get_scheme
    from repro.sharding import shard_map

    D = 512
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("dev",))
    grads = jnp.asarray(
        jax.random.normal(jax.random.PRNGKey(7), (n_dev, D)))
    deltas = jnp.zeros((n_dev, D))
    outs = {}
    for uk in (False, True):
        cfg = OTAConfig(scheme="a_dsgd", projection="blocked", block_size=64,
                        s_frac=0.5, k_frac=0.25, rademacher=True, p_avg=500.0,
                        total_steps=10, amp_iters=5, mean_removal_steps=0,
                        use_kernel=uk)
        sch = get_scheme(cfg, D, n_dev)
        ctx = MACContext(m=n_dev, device_axes=("dev",), d_pad=D,
                         chunk_blocks=4, use_kernel=uk)

        def body(g, dl):
            ghat, nd, _ = distributed.sharded_round(
                sch, g.reshape(-1), dl.reshape(-1), 0,
                jax.random.PRNGKey(3), ctx)
            return ghat

        outs[uk] = shard_map(body, mesh=mesh, in_specs=(P("dev"), P("dev")),
                             out_specs=P(), axis_names={"dev"},
                             check_vma=False)(grads, deltas)
    np.testing.assert_allclose(np.asarray(outs[True]),
                               np.asarray(outs[False]),
                               rtol=1e-4, atol=1e-5)
