"""Partitioning subsystem: IID, label shards, Dirichlet(beta) bias knob."""
import numpy as np
import pytest

from repro.data.partition import (
    label_bias, label_shard_assignment, make_partition, partition_dirichlet,
    partition_iid, partition_label_shards, population_label_bias,
    population_partition,
)
from repro.data.synthetic import federated_split, make_classification

M, B, C = 10, 80, 10


@pytest.fixture(scope="module")
def data():
    (x, y), _ = make_classification(n_train=4000, n_test=10, seed=0)
    return x, y


def test_iid_shapes_and_no_replacement(data):
    x, y = data
    idx = partition_iid(y, M, B, seed=0)
    assert idx.shape == (M, B)
    assert len(np.unique(idx)) == M * B  # without replacement


def test_label_shard_groups_cover_all_classes_exactly_once(data):
    # m * spd == n_classes: a single shard group -> every class exactly once
    assign = label_shard_assignment(m=5, shards_per_device=2, n_classes=C,
                                    seed=0)
    assert sorted(assign.reshape(-1).tolist()) == list(range(C))
    # two full groups: every class appears exactly twice globally
    assign2 = label_shard_assignment(m=C, shards_per_device=2, n_classes=C,
                                     seed=0)
    counts = np.bincount(assign2.reshape(-1), minlength=C)
    np.testing.assert_array_equal(counts, np.full(C, 2))


def test_label_shard_devices_always_hold_distinct_classes():
    """The paper protocol: two shards => exactly two classes per device —
    no seed may deal a device the same class twice (max-remaining-first
    dealing guarantees it whenever shards_per_device <= n_classes)."""
    for seed in range(30):
        for m, spd in ((10, 2), (5, 2), (8, 2), (25, 2), (4, 5)):
            assign = label_shard_assignment(m, spd, n_classes=C, seed=seed)
            for dev in range(m):
                assert len(set(assign[dev].tolist())) == spd, (m, spd, seed)
            counts = np.bincount(assign.reshape(-1), minlength=C)
            assert counts.max() - counts.min() <= 1


def test_label_shard_partition_matches_assignment(data):
    x, y = data
    idx = partition_label_shards(y, m=5, b=B, shards_per_device=2, seed=3)
    labels = y[idx]
    # each device holds exactly its 2 assigned classes
    assign = label_shard_assignment(5, 2, C, seed=3)
    for dev in range(5):
        assert set(np.unique(labels[dev])) == set(assign[dev].tolist())
    # one shard group in total: the 5 devices cover all 10 classes
    assert set(np.unique(labels)) == set(range(C))


def test_dirichlet_large_beta_recovers_iid(data):
    x, y = data
    idx = partition_dirichlet(y, M, B, beta=1e6, seed=0)
    bias_inf = label_bias(y[idx], C)
    bias_iid = label_bias(y[partition_iid(y, M, B, seed=0)], C)
    # beta -> inf: per-device class marginals match the IID split's
    assert bias_inf < bias_iid + 0.1
    assert bias_inf < 0.2


def test_dirichlet_bias_monotone_in_beta(data):
    x, y = data
    biases = {}
    for beta in (0.05, 1.0, 100.0):
        idx = partition_dirichlet(y, M, B, beta=beta, seed=0)
        biases[beta] = label_bias(y[idx], C)
    assert biases[0.05] > biases[1.0] > biases[100.0]
    assert biases[0.05] > 0.5          # heavy skew
    assert biases[100.0] < 0.2         # near-IID


def test_label_bias_extremes():
    # every device one class -> TV = (C-1)/C; uniform -> 0
    y_dev = np.repeat(np.arange(C), B).reshape(C, B)
    assert label_bias(y_dev, C) == pytest.approx((C - 1) / C)
    y_uniform = np.tile(np.arange(C), (M, B // C))
    assert label_bias(y_uniform, C) == pytest.approx(0.0)


def test_make_partition_kinds_and_errors(data):
    x, y = data
    for kind in ("iid", "label_shards", "dirichlet"):
        xd, yd = make_partition(x, y, M, B, kind=kind, beta=0.5)
        assert xd.shape == (M, B, x.shape[1]) and yd.shape == (M, B)
    with pytest.raises(ValueError, match="unknown partition kind"):
        make_partition(x, y, M, B, kind="quantum")


# ---------------------------------------------------------------------------
# population-scale arithmetic partitions (no (M, B) table)
# ---------------------------------------------------------------------------


def test_population_iid_covers_pool_and_scales_to_1e5(data):
    x, y = data
    n = len(y)
    # m*b == n: the windows tile one shuffled epoch exactly (disjoint cover)
    part = population_partition(y, m=n // B, b=B, kind="iid", seed=0)
    idx = np.asarray(part.sample_indices(np.arange(n // B)))
    assert idx.shape == (n // B, B)
    assert len(np.unique(idx)) == n
    # M = 1e5 over the same pool: O(N) state only, cohort rows on demand
    big = population_partition(y, m=100_000, b=B, kind="iid", seed=0)
    assert big.order.shape == (n,)
    cohort = np.asarray([0, 7, 99_999])
    rows = np.asarray(big.sample_indices(cohort))
    assert rows.shape == (3, B)
    assert rows.min() >= 0 and rows.max() < n
    # device m's window is reproducible arithmetic on the one permutation
    np.testing.assert_array_equal(
        rows[2], big.order[(99_999 * B + np.arange(B)) % n])


def test_population_label_shards_matches_device_classes(data):
    x, y = data
    part = population_partition(y, m=50_000, b=B, kind="label_shards",
                                shards_per_device=2, seed=1)
    for dev in (0, 3, 777, 49_999):
        classes = part.device_labels(dev)
        assert len(set(classes.tolist())) == 2  # spd distinct classes
        got = y[np.asarray(part.sample_indices(np.asarray([dev])))[0]]
        assert set(np.unique(got)) == set(classes.tolist())
        counts = np.bincount(got, minlength=C)
        assert counts[classes[0]] == counts[classes[1]] == B // 2


def test_population_label_bias_consistent_under_subsampling(data):
    x, y = data
    part = population_partition(y, m=2000, b=B, kind="label_shards",
                                shards_per_device=2, seed=0)
    full = population_label_bias(part, y, n_classes=C)
    # subsample at random — a strided subsample would alias with the
    # class-cycling period and see a collapsed class marginal
    devices = np.random.default_rng(0).choice(2000, 200, replace=False)
    sample = population_label_bias(part, y, devices=devices, n_classes=C)
    assert full == pytest.approx(sample, abs=0.02)
    assert full > 0.5  # two-class devices are heavily biased
    iid_part = population_partition(y, m=2000, b=B, kind="iid", seed=0)
    assert population_label_bias(iid_part, y, n_classes=C) < full


def test_population_partition_rejects_bad_configs(data):
    x, y = data
    with pytest.raises(ValueError, match="shards_per_device <= "):
        population_partition(y, m=10, b=B, kind="label_shards",
                             shards_per_device=C + 1)
    with pytest.raises(ValueError, match=r"shards_per_device \| b"):
        population_partition(y, m=10, b=B + 1, kind="label_shards",
                             shards_per_device=2)
    with pytest.raises(ValueError, match="dirichlet|unknown"):
        population_partition(y, m=10, b=B, kind="dirichlet")


def test_federated_split_delegates(data):
    x, y = data
    xd, yd = federated_split(x, y, m=M, b=B, iid=False, seed=0)
    assert all(len(np.unique(yy)) <= 2 for yy in yd)
    xb, yb = federated_split(x, y, m=M, b=B, kind="dirichlet", beta=0.1,
                             seed=0)
    assert label_bias(yb, C) > label_bias(yd, C) * 0 + 0.3
