"""Prefill-vs-decode consistency: step-by-step decode with a KV/state cache
must reproduce the full-sequence forward (teacher forcing equality)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_decode_cache, init_params
from repro.models import transformer

B = 2


def _decode_errs(cfg, params, toks, enc_out=None, decode_window=None):
    L = toks.shape[1]
    full, _, _ = transformer.forward(params, cfg, toks, enc_out=enc_out,
                                     compute_dtype=jnp.float32)
    cache = init_decode_cache(cfg, B, L, dtype=jnp.float32,
                              decode_window=decode_window)
    errs = []
    for t in range(L):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache, t,
                                enc_out=enc_out, compute_dtype=jnp.float32,
                                decode_window=decode_window)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    return max(errs)


@pytest.mark.parametrize("arch", ["smollm_360m", "rwkv6_3b", "zamba2_7b",
                                  "granite_moe_1b_a400m", "qwen3_8b"])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0, cfg.vocab)
    assert _decode_errs(cfg, params, toks) < 5e-3


def test_sliding_window_ring_cache():
    cfg = dataclasses.replace(get_config("yi_34b").reduced(), sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 20), 0, cfg.vocab)
    # ring cache of 8 slots vs full-forward with window masking
    assert _decode_errs(cfg, params, toks, decode_window=8) < 5e-3


def test_whisper_decode_with_cross_attention():
    cfg = get_config("whisper_base").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    frames = 0.02 * jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.encoder.d_model))
    enc = transformer.encode_audio(params, cfg, frames.astype(jnp.float32))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 10), 0, cfg.vocab)
    assert _decode_errs(cfg, params, toks, enc_out=enc) < 5e-3


def test_mamba2_chunked_equals_sequential():
    from repro.configs.base import SSMConfig
    from repro.models.ssm import (init_mamba2, init_mamba2_state,
                                  mamba2_forward)
    cfg = SSMConfig(d_state=8, expand=2, head_dim=16, conv_width=4, chunk=8)
    d_model = 32
    p = init_mamba2(jax.random.PRNGKey(0), d_model, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, 24, d_model))
    y_chunk, _ = mamba2_forward(p, x, d_model, cfg, None)
    st = init_mamba2_state(cfg, d_model, B)
    ys = []
    for t in range(24):
        yt, st = mamba2_forward(p, x[:, t:t + 1], d_model, cfg, st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    assert float(jnp.max(jnp.abs(y_chunk - y_seq))) < 1e-3
