"""Property tests (hypothesis) for the compression primitives (paper §III/IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra "
    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression as C

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


vec = st.integers(0, 2 ** 31 - 1).flatmap(
    lambda seed: st.integers(8, 256).map(
        lambda n: np.asarray(
            np.random.default_rng(seed).normal(size=n), np.float32)))


@given(vec, st.integers(1, 64))
def test_topk_support_and_energy(v, k):
    k = min(k, v.shape[0])
    out = np.asarray(C.top_k_sparsify(jnp.asarray(v), k))
    nz = np.count_nonzero(out)
    assert nz <= v.shape[0]
    # all kept entries are >= every dropped entry in magnitude
    if nz and nz < v.shape[0]:
        kept = np.abs(out[out != 0]).min()
        dropped = np.abs(v[out == 0]).max() if (out == 0).any() else 0.0
        assert kept >= dropped - 1e-6
    # keeps at least k entries' energy (ties may add more)
    assert nz >= min(k, np.count_nonzero(v))


@given(vec, st.integers(1, 32))
def test_error_feedback_conservation(v, k):
    delta = np.roll(v, 3) * 0.5
    g_ec = C.error_feedback(jnp.asarray(v), jnp.asarray(delta))
    g_sp = C.top_k_sparsify(g_ec, min(k, v.shape[0]))
    new_delta = C.residual(g_ec, g_sp)
    np.testing.assert_allclose(np.asarray(g_sp + new_delta),
                               v + delta, rtol=1e-5, atol=1e-6)


@given(vec, st.integers(1, 16))
def test_sbc_quantize_structure(v, q):
    """D-DSGD quantizer output has a single nonzero magnitude (paper §III)."""
    out = np.asarray(C.sbc_quantize(jnp.asarray(v), q, q_max=16))
    mags = np.unique(np.abs(out[out != 0]))
    assert len(mags) <= 1
    if len(mags) == 1:
        # the surviving side's sign is consistent
        assert (out >= 0).all() or (out <= 0).all()


@given(vec, st.integers(1, 16))
def test_signsgd_values(v, q):
    out = np.asarray(C.signsgd_compress(jnp.asarray(v), q, q_max=16))
    assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})
    assert np.count_nonzero(out) <= 16 + 8  # q_max plus magnitude ties


def test_qsgd_unbiased():
    v = jnp.asarray(np.random.default_rng(0).normal(size=64), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), 600)
    outs = jax.vmap(lambda k: C.qsgd_compress(v, 64, 64, 2, k))(keys)
    np.testing.assert_allclose(np.asarray(outs.mean(0)), np.asarray(v),
                               atol=0.12)


def test_bit_budget_and_q_schedule():
    d, s, m, sigma2 = 7850, 3925, 25, 1.0
    p = np.full(10, 500.0)
    budgets = C.mac_bit_budget(s, m, p, sigma2)
    assert (budgets > 0).all()
    qs = C.digital_q_schedule(d, s, m, p, sigma2, scheme="d_dsgd")
    assert (qs >= 0).all()
    # chosen q fits the budget, q+1 does not
    for q, b in zip(qs, budgets):
        assert C.ddsgd_bits(d, np.asarray([float(q)]))[0] <= b + 1e-9
        if q < d // 2:
            assert C.ddsgd_bits(d, np.asarray([float(q + 1)]))[0] > b


def test_more_power_more_bits():
    d, s, m = 7850, 3925, 25
    q_lo = C.digital_q_schedule(d, s, m, np.asarray([100.0]), 1.0)[0]
    q_hi = C.digital_q_schedule(d, s, m, np.asarray([1000.0]), 1.0)[0]
    assert q_hi >= q_lo


@given(vec)
def test_sampled_threshold_brackets_exact(v):
    if v.shape[0] < 16:
        return
    k = max(1, v.shape[0] // 4)
    tau_exact = float(C.topk_threshold(jnp.asarray(v), k))
    tau_approx = float(C.sampled_topk_threshold(jnp.asarray(v), k,
                                                jax.random.PRNGKey(0),
                                                n_samples=v.shape[0]))
    mag = np.sort(np.abs(v))
    # approx threshold must be a plausible magnitude within the vector range
    assert mag[0] - 1e-6 <= tau_approx <= mag[-1] + 1e-6
    # with full sampling it should be close to the exact k-th magnitude
    assert abs(tau_approx - tau_exact) <= (mag[-1] - mag[0]) * 0.3 + 1e-5
