"""Streamed OTA-DSGD over the LLM param tree (train/fedllm.py).

Pins the acceptance criteria: >= 2 OTA rounds over reduced smollm_360m
with serving between rounds, served params bitwise-equal the decoded
globals, pipelined streaming bitwise-equal the per-chunk reference,
EF accumulators persisting per chunk, and mid-sweep checkpoint/resume
bitwise-equal to the uninterrupted run.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OTAConfig, TrainConfig
from repro.experiments.engine import round_keys, run_checkpointed
from repro.train.fedllm import (CompiledFedLLM, serve_while_train,
                                stream_round, stream_round_masked,
                                stream_round_ref)


def _fed(chunk_size=1 << 14, m=3, scheme="a_dsgd", use_kernel=False):
    arch = get_config("smollm_360m").reduced()
    ota = OTAConfig(scheme=scheme, projection="blocked", s_frac=0.25,
                    k_frac=0.5, block_size=256, use_kernel=use_kernel)
    tc = TrainConfig(compute_dtype="float32")
    return CompiledFedLLM(arch, tc, ota, m=m, batch=2, seq_len=8,
                          chunk_size=chunk_size, seed=0)


def _chunked_grads(fed, key):
    carry = fed.carry0()
    g, _ = jax.jit(fed._grads)(carry[0], key)
    gch = g.reshape(fed.m, fed.n_chunks,
                    fed.chunk_len).transpose(1, 0, 2)
    return carry, gch


def test_two_rounds_smoke():
    fed = _fed()
    assert fed.n_chunks >= 2        # the stream is actually chunked
    outs = fed.run(round_keys(2, 0))
    losses = np.asarray(outs["loss"])
    assert losses.shape == (2,) and np.isfinite(losses).all()
    assert np.isfinite(np.asarray(outs["metrics"]["active_frac"])).all()


def test_pipelined_stream_matches_reference_bitwise():
    fed = _fed()
    key = round_keys(1, 0)[0]
    carry, gch = _chunked_grads(fed, key)
    a = jax.jit(lambda: stream_round(fed.scheme, gch, carry[2], 0, key,
                                     fed.ctx))()
    b = jax.jit(lambda: stream_round_ref(fed.scheme, gch, carry[2], 0, key,
                                         fed.ctx))()
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_masked_stream_all_ones_matches_pipelined_bitwise():
    fed = _fed()
    key = round_keys(1, 0)[0]
    carry, gch = _chunked_grads(fed, key)
    mask = jnp.ones((fed.m,), jnp.float32)
    a = jax.jit(lambda: stream_round(fed.scheme, gch, carry[2], 0, key,
                                     fed.ctx))()
    b = jax.jit(lambda: stream_round_masked(fed.scheme, gch, carry[2], 0,
                                            key, mask, fed.ctx))()
    # round_masked returns a superset of metrics; compare the shared core
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b[i]))
    for k, v in a[2].items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(b[2][k]))


def test_ef_state_persists_per_chunk():
    fed = _fed()
    keys = round_keys(2, 0)
    seg = jax.jit(lambda k, c, t: fed.run_segment({}, k, None, c, t))
    carry1, _ = seg(keys[:1], fed.carry0(), jnp.int32(0))
    deltas1 = np.asarray(carry1[2])
    assert deltas1.shape == (fed.n_chunks, fed.m, fed.chunk_len)
    # a_dsgd banks sparsification error: EF must be live in every full
    # chunk (the tail chunk is mostly pad — its few real entries can all
    # survive top-k, banking exactly zero)
    per_chunk = np.abs(deltas1).sum(axis=(1, 2))
    assert (per_chunk[:-1] > 0).all()
    carry2, _ = seg(keys[1:], carry1, jnp.int32(1))
    assert not np.array_equal(deltas1, np.asarray(carry2[2]))


def test_kernel_encode_path_on_streamed_chunks():
    """use_kernel=True routes chunk encodes through ef_sparsify_pallas
    (prime-safe since the pad fix); parity with the jnp path."""
    key = round_keys(1, 0)[0]
    fed_k = _fed(use_kernel=True)
    fed_r = _fed(use_kernel=False)
    carry, gch = _chunked_grads(fed_r, key)
    gch1, dl1 = gch[:1], carry[2][:1]       # one chunk is enough
    a = jax.jit(lambda: stream_round(fed_k.scheme, gch1, dl1, 0, key,
                                     fed_k.ctx))()
    b = jax.jit(lambda: stream_round(fed_r.scheme, gch1, dl1, 0, key,
                                     fed_r.ctx))()
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_serve_while_train_demo():
    arch = get_config("smollm_360m").reduced()
    ota = OTAConfig(projection="blocked", s_frac=0.25, k_frac=0.5,
                    block_size=256)
    tc = TrainConfig(compute_dtype="float32")
    out = serve_while_train(arch, rounds=2, ota=ota, train_cfg=tc, m=3,
                            batch=2, seq_len=8, chunk_size=1 << 14,
                            serve_batch=2, prompt_len=3, decode_steps=2,
                            seed=0)
    # >= 2 OTA rounds completed, >= 1 decode batch served between rounds
    assert out["losses"].shape == (2,)
    assert np.isfinite(out["losses"]).all()
    assert len(out["served_tokens"]) == 2
    assert out["served_tokens"][0].shape == (2, 2)
    # params served after round t bitwise-equal the decoded globals
    assert out["publish_bitwise"]


@pytest.mark.slow
def test_checkpoint_resume_bitwise():
    fed = _fed()
    keys = round_keys(3, 0)
    with tempfile.TemporaryDirectory() as td1, \
            tempfile.TemporaryDirectory() as td2:
        full = run_checkpointed(fed, {}, keys, checkpoint_dir=td1,
                                checkpoint_every=2)
        half = run_checkpointed(fed, {}, keys, checkpoint_dir=td2,
                                checkpoint_every=2, stop_after_step=2)
        assert half is None                    # interrupted mid-sweep
        resumed = run_checkpointed(fed, {}, keys, checkpoint_dir=td2,
                                   checkpoint_every=2, resume=True)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
