"""AMP reconstruction properties (paper §IV / Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amp import (amp_decode, amp_decode_blocked,
                            amp_decode_blocked_scan, amp_decode_dense)
from repro.core.projection import BlockedProjector, DenseProjector


def _sparse_signal(key, d, k, scale=1.0):
    idx = jax.random.choice(key, d, (k,), replace=False)
    vals = jax.random.normal(jax.random.fold_in(key, 1), (k,)) * scale
    return jnp.zeros(d).at[idx].set(vals)


def test_amp_recovers_sparse_dense_matrix():
    d, k, s = 2048, 64, 512
    proj = DenseProjector(d=d, s_tilde=s, seed=3)
    x = _sparse_signal(jax.random.PRNGKey(0), d, k)
    y = proj.project(x)
    xh = amp_decode_dense(y, proj.matrix(), iters=30)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.05, rel


def test_amp_noise_robust():
    d, k, s = 2048, 64, 512
    proj = DenseProjector(d=d, s_tilde=s, seed=3)
    x = _sparse_signal(jax.random.PRNGKey(0), d, k, scale=5.0)
    z = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (s,))
    xh = amp_decode_dense(proj.project(x) + z, proj.matrix(), iters=30)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.15, rel


def test_amp_blocked_recovery_and_scan_equivalence():
    d, c, sb = 4096, 256, 128
    proj = BlockedProjector(d=d, block_size=c, s_block=sb, seed=5)
    # per-block sparse signal (k_b ~ s_b/4)
    xb = []
    for b in range(d // c):
        xb.append(_sparse_signal(jax.random.PRNGKey(b), c, sb // 4))
    x = jnp.concatenate(xb)
    y = proj.project(x)
    xh = amp_decode(y, proj, iters=30)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < 0.1, rel
    # the chunked-scan decoder matches the batched one
    yb = y.reshape(proj.n_blocks, sb)
    x_scan = amp_decode_blocked_scan(yb, proj, iters=30)
    x_batch = amp_decode_blocked(yb, proj, iters=30)
    np.testing.assert_allclose(np.asarray(x_scan), np.asarray(x_batch),
                               rtol=1e-4, atol=1e-5)


def test_debias_reduces_shrinkage():
    d, k, s = 2048, 64, 512
    proj = DenseProjector(d=d, s_tilde=s, seed=3)
    x = _sparse_signal(jax.random.PRNGKey(0), d, k)
    y = proj.project(x)
    xh_raw = amp_decode_dense(y, proj.matrix(), iters=15, debias=False)
    xh_db = amp_decode_dense(y, proj.matrix(), iters=15, debias=True)
    err_raw = float(jnp.linalg.norm(xh_raw - x))
    err_db = float(jnp.linalg.norm(xh_db - x))
    assert err_db <= err_raw + 1e-6


def test_effective_noise_contracts_with_iters():
    """Lemma 1: reconstruction error decreases monotonically-ish in iters."""
    d, k, s = 2048, 64, 512
    proj = DenseProjector(d=d, s_tilde=s, seed=3)
    x = _sparse_signal(jax.random.PRNGKey(0), d, k)
    y = proj.project(x)
    errs = [float(jnp.linalg.norm(
        amp_decode_dense(y, proj.matrix(), iters=i) - x)) for i in (2, 8, 30)]
    assert errs[2] < errs[0]


def test_dense_matrix_cache_is_host_side_and_clearable():
    """The dense A cache must hold host (numpy) copies — not pin device
    buffers across sweeps/backends — and regenerate bitwise after clear."""
    from repro.core import projection as projection_mod
    projection_mod.clear_dense_cache()
    m1 = np.asarray(projection_mod._dense_matrix(11, 32, 64))
    cached = projection_mod._DENSE_CACHE[(11, 32, 64)]
    assert isinstance(cached, np.ndarray)          # host-side storage
    m2 = np.asarray(projection_mod._dense_matrix(11, 32, 64))
    np.testing.assert_array_equal(m1, m2)
    projection_mod.clear_dense_cache()
    assert not projection_mod._DENSE_CACHE
    np.testing.assert_array_equal(
        m1, np.asarray(projection_mod._dense_matrix(11, 32, 64)))


def test_dense_matrix_cache_bounded():
    from repro.core import projection as projection_mod
    projection_mod.clear_dense_cache()
    for seed in range(projection_mod._DENSE_CACHE_MAX + 3):
        projection_mod._dense_matrix(seed, 4, 8)
    assert len(projection_mod._DENSE_CACHE) <= projection_mod._DENSE_CACHE_MAX
    projection_mod.clear_dense_cache()
