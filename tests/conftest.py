import os

# Tests run on the single real CPU device (the dry-run, and ONLY the dry-run,
# forces 512 host devices — never set that here).  Multi-device trainer tests
# spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
