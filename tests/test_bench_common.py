"""benchmarks/common.py edges: the CSV contract every figure script and
benchmarks/run.py parse by position (``figure,series,step,acc`` rows and
``(name, us_per_call, final_acc)`` summary triples)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common  # noqa: E402

M, B, DIM = 4, 32, 24


@pytest.fixture(scope="module")
def tiny_data():
    from repro.data.synthetic import federated_split, make_classification
    (xtr, ytr), (xte, yte) = make_classification(
        n_train=400, n_test=120, dim=DIM, noise=2.0, seed=3)
    xd, yd = federated_split(xtr, ytr, m=M, b=B, iid=True, seed=0)
    return (xd, yd), (xte, yte)


def test_sweep_series_csv_schema_stable(tiny_data):
    """Rows are exactly ``fig,series,step,acc`` with 4-decimal accuracy,
    one per eval point per grid point; summary triples are
    ``(fig_series, us_per_call, final_acc)`` — the shape run.py and the
    CI plots consume."""
    dev, test = tiny_data
    rows = []
    steps = 6
    res, summary = common.sweep_series(
        "figX", dev, test, {"seed": [0, 1]},
        lambda rec: f"s{rec['seed']}", rows=rows, steps=steps,
        scheme="ideal")
    n_evals = len(res.records[0]["accs"])
    assert len(rows) == 2 * n_evals
    for row in rows:
        fig, series, step, acc = row.split(",")
        assert fig == "figX" and series in ("s0", "s1")
        assert 0 <= int(step) <= steps - 1
        assert acc == f"{float(acc):.4f}"        # fixed 4-decimal format
    # eval steps clamp to the last round, never past it
    assert int(rows[n_evals - 1].split(",")[2]) == steps - 1
    assert [name for name, _, _ in summary] == ["figX_s0", "figX_s1"]
    for _, us, final in summary:
        assert us > 0 and 0.0 <= final <= 1.0


def test_sweep_series_scheme_axis_names_series(tiny_data):
    dev, test = tiny_data
    rows = []
    _, summary = common.sweep_series(
        "figY", dev, test, {"scheme": ["ideal", "d_dsgd"]},
        lambda rec: rec["scheme"], rows=rows, steps=4)
    assert {n for n, _, _ in summary} == {"figY_ideal", "figY_d_dsgd"}
    assert {r.split(",")[1] for r in rows} == {"ideal", "d_dsgd"}


def test_emit_prints_header_then_rows(capsys):
    common.emit(["f,s,0,0.5000"])
    out = capsys.readouterr().out.splitlines()
    assert out == ["figure,series,step,test_accuracy", "f,s,0,0.5000"]


def test_ota_rejects_unknown_scheme():
    with pytest.raises(KeyError, match="unknown scheme"):
        common.ota("not_a_scheme")
